//! The scenario runner — one named (workload × faults × config) case
//! executed to quiescence on virtual time.
//!
//! A [`Scenario`] assembles the REAL serving stack — trained
//! [`Model`]s in a sharded [`ModelStore`], ONE routed [`BatchServer`]
//! collector serving every model name, an optional prioritized
//! [`FitQueue`] worker pool — all on one [`Clock::sim`], then drives
//! the discrete-event loop:
//!
//! 1. wait for **quiescence** (every component thread parked with
//!    nothing to do — see [`SimClock::until_quiescent`]);
//! 2. observe: poll finished fit jobs (recording hot-swap publishes),
//!    drain completed predict tickets (stamping exact virtual
//!    latencies and checking batch bit-identity per response);
//! 3. advance virtual time to the next instant anything happens — the
//!    earlier of the next workload/fault event and the components' own
//!    next deadline ([`SimClock::next_deadline`], e.g. a collector's
//!    `max_wait` flush). Ties resolve deadline-first, so an arrival at
//!    exactly a flush instant deterministically joins the *next* batch.
//!
//! Because threads only make progress between quiescence points and
//! the driver serializes every injection, the resulting [`Outcome`] —
//! batch composition, latency percentiles, fault counters — is a pure
//! function of the scenario, independent of machine speed, OS
//! scheduling, and fit-queue worker count. Running a scenario twice
//! (or with 1 vs 8 workers) must produce `==` outcomes;
//! `tests/simserve.rs` enforces exactly that.
//!
//! Most faults are injected through the fit queue; `TicketDrop` and
//! `Rebalance` are *driver-side* — the runner drops live predict
//! tickets (exercising cancellation propagation: the rows must cost no
//! flush work) or calls the store's rebalance and snapshots per-shard
//! loads around it. A scenario may also name a
//! [`victim_model`](Scenario::victim_model) whose latencies are
//! tracked separately — the fairness A/B observable.
//!
//! **Bit-identity under faults:** every drained response is checked
//! bit-for-bit against a one-at-a-time [`Model::predict`] /
//! `decision_function` / `predict_proba` on the model *version* that
//! served it. A mismatch panics — no fault scenario is allowed to bend
//! the serving determinism contract.

use super::clock::{Clock, Tick};
use super::faults::Fault;
use super::workload::{Arrival, WorkloadSpec};
use crate::api::serve::{
    batch_design, BatchConfig, BatchServer, FitFault, FitJob, FitQueue, JobId, JobPriority,
    JobState, ModelStore, PendingPredict,
};
use crate::api::{Fit, Model, ShotgunError};
use crate::data::synth;
use crate::objective::Loss;
use crate::sparsela::Design;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// One named simulation case (see module docs).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Stable name (scenario suite key, JSON report key).
    pub name: &'static str,
    /// The traffic.
    pub workload: WorkloadSpec,
    /// Batching policy of every server in the scenario.
    pub batch: BatchConfig,
    /// Scheduled disturbances (empty = serve-only scenario).
    pub faults: Vec<Fault>,
    /// Fit-queue worker threads (only spawned if a fault needs them).
    pub fit_workers: usize,
    /// Fit-queue bounded capacity.
    pub fit_capacity: usize,
    /// `ModelStore` shard count (0 clamps to 1).
    pub store_shards: usize,
    /// Workload + request-content seed.
    pub seed: u64,
    /// Loss of the served models (decides predict semantics).
    pub loss: Loss,
    /// Training rows for the pre-fitted models.
    pub train_n: usize,
    /// Regularization of the pre-fitted models.
    pub train_lam: f64,
    /// Track this model's latencies separately and report their p99 in
    /// [`Outcome::victim_p99_us`] — the fairness A/B observable (the
    /// non-flooding tenant in the flooding-tenant scenarios).
    pub victim_model: Option<usize>,
}

/// Typed outcome stats of one scenario run. `PartialEq` on purpose:
/// determinism tests assert run-to-run (and worker-count) equality of
/// the WHOLE struct, floats included — equal runs must produce
/// bit-equal numbers.
#[derive(Clone, Debug, PartialEq)]
pub struct Outcome {
    pub name: String,
    /// Requests submitted / successful responses / typed failures.
    pub requests: u64,
    pub responses: u64,
    pub failed_responses: u64,
    /// Tickets resolved `Err(ServerShutdown)` — the reply channel died
    /// before serving (0 in every healthy scenario).
    pub shutdown_responses: u64,
    /// Requests shed with a typed `Err(Overloaded)` by the admission
    /// gate (`BatchConfig::max_in_flight`).
    pub overloaded_responses: u64,
    /// Coalesced batches across all servers, and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Virtual end-to-end duration and served throughput over it.
    pub virtual_seconds: f64,
    pub throughput_rps: f64,
    /// Virtual submit→reply latency percentiles, microseconds.
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    /// Responses checked bit-for-bit against sequential predict.
    pub bit_identity_checked: u64,
    /// Fit-queue terminal counts (0 when the scenario has no queue).
    pub completed_jobs: u64,
    pub failed_jobs: u64,
    /// Typed overload rejections from the bounded queue.
    pub rejected_jobs: u64,
    /// Jobs that failed typed `DeadlineExpired` at dequeue (a
    /// `PriorityBurst`'s doomed Normal jobs) — never run, never counted
    /// in `failed_jobs`.
    pub expired_jobs: u64,
    /// The instant a `PriorityBurst`'s High job completed, how many of
    /// its Batch fillers it beat (still queued or running). Equals the
    /// burst's `batch_jobs` when the lanes work; 0 without a burst.
    pub high_lead_jobs: u64,
    /// Hot-swap publish → first response served by the new version
    /// (virtual µs), when the scenario hot-swaps.
    pub swap_lag_us: Option<f64>,
    /// Batches flushed between the worker-panic injection and the
    /// recovery publish becoming visible, when the scenario injects
    /// both.
    pub recovery_batches: Option<u64>,
    /// Highest model version that served a response.
    pub max_version_served: u64,
    /// Predict tickets the driver dropped mid-flight
    /// ([`Fault::TicketDrop`]) — shed clients whose rows must cost no
    /// `decision_function` work.
    pub cancelled_requests: u64,
    /// Pending rows the router skipped at flush because their ticket
    /// was dropped (the server's own cancellation counter; covers
    /// every server in the scenario).
    pub cancelled_rows: u64,
    /// p99 latency (virtual µs) over the victim model's responses,
    /// when the scenario names a [`Scenario::victim_model`].
    pub victim_p99_us: Option<f64>,
    /// [`Fault::DeadlineBurst`] accounting: jobs submitted with
    /// deadlines, and how many completed within them (EDF observable).
    pub deadline_jobs: u64,
    pub deadline_met_jobs: u64,
    /// [`Fault::Rebalance`] accounting: names re-homed, and the
    /// hottest shard's share of routed store reads before/after the
    /// move (1.0 = one shard took every read in that window).
    pub rebalance_moved: Option<u64>,
    pub hot_share_before: Option<f64>,
    pub hot_share_after: Option<f64>,
}

/// Latency percentile by nearest-rank on a sorted slice.
fn percentile(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn model_name(idx: usize) -> String {
    format!("m{idx}")
}

fn solver_for(loss: Loss) -> &'static str {
    if loss.classifies() {
        "shooting-cdn"
    } else {
        "shooting"
    }
}

/// What a pending fit job was injected for.
#[derive(Clone, Copy, Debug, PartialEq)]
enum JobKind {
    /// `Fault::WorkerPanic`'s poisoned job.
    Panic,
    /// `Fault::HotSwap`'s refit (publishes model 0).
    Swap,
    /// `Fault::QueueSaturation`'s worker-wedging slow job.
    Wedge,
    /// `Fault::QueueSaturation`'s burst filler.
    Burst,
    /// `Fault::PriorityBurst`'s High-lane job (submitted LAST).
    HighPri,
    /// `Fault::PriorityBurst`'s Batch-lane slow filler.
    BatchFiller,
    /// `Fault::PriorityBurst`'s doomed Normal job — its deadline lapses
    /// while the workers are wedged, so it must fail typed at dequeue.
    Expired,
    /// `Fault::DeadlineBurst`'s dated Normal job — under EDF every one
    /// of them is dequeued inside its deadline and completes.
    DeadlineJob,
}

enum Ev {
    Arrive(usize),
    Fault(usize),
}

struct InFlight {
    submitted: Tick,
    arrival: usize,
    ticket: PendingPredict,
}

/// Everything the drain/poll observers mutate.
struct Observed {
    latencies_us: Vec<f64>,
    /// Latencies of the victim model's responses only (fairness A/B).
    victim_latencies_us: Vec<f64>,
    responses: u64,
    failed_responses: u64,
    shutdown_responses: u64,
    overloaded_responses: u64,
    bit_checked: u64,
    max_version: u64,
    completed_jobs: u64,
    failed_jobs: u64,
    expired_jobs: u64,
    /// Set once, the first poll that sees the High job Done.
    high_lead_jobs: Option<u64>,
    /// `(publish tick, published version)` of the hot-swap, once its
    /// job completes.
    swap_published: Option<(Tick, u64)>,
    swap_visible_at: Option<Tick>,
    /// All-server batch count when the panic was injected / when the
    /// swap became visible.
    panic_batches: Option<u64>,
    recovery_batches: Option<u64>,
    /// Tickets the driver dropped (`Fault::TicketDrop`).
    cancelled_requests: u64,
    /// `Fault::DeadlineBurst` totals: submitted with deadlines / done.
    deadline_jobs: u64,
    deadline_met_jobs: u64,
    /// Per-shard store loads at the `Fault::Rebalance` instant, and
    /// how many names the rebalance moved.
    rebalance_loads_before: Option<Vec<u64>>,
    rebalance_moved: Option<u64>,
}

/// Run the scenario to quiescence (see module docs).
pub fn run(sc: &Scenario) -> Result<Outcome, ShotgunError> {
    let models = sc.workload.models.max(1);
    let d = sc.workload.d;
    let clock = Clock::sim();
    let sim = Arc::clone(clock.sim_handle().expect("sim clock"));
    let store = Arc::new(ModelStore::with_shards(sc.store_shards));

    // -- pre-sim: train + publish one real model per name (virtual t=0)
    let mut versions: HashMap<(usize, u64), Arc<Model>> = HashMap::new();
    let mut train0: Option<(Arc<Design>, Arc<Vec<f64>>)> = None;
    for m in 0..models {
        let ds = if sc.loss.classifies() {
            synth::rcv1_like(sc.train_n, d, 0.1, sc.seed.wrapping_add(m as u64))
        } else {
            synth::sparse_imaging(sc.train_n, d, 0.1, sc.seed.wrapping_add(m as u64))
        };
        let design = Arc::new(ds.design);
        let targets = Arc::new(ds.targets);
        let report = Fit::new(&design, &targets)
            .loss(sc.loss)
            .lambda(sc.train_lam)
            .solver(solver_for(sc.loss))
            .options(|o| {
                o.max_iters = 200_000;
                o.tol = 1e-6;
            })
            .run()?;
        store.publish(&model_name(m), report.model);
        let rec = store.get(&model_name(m)).expect("just published");
        versions.insert((m, rec.version), Arc::clone(&rec.model));
        if m == 0 {
            train0 = Some((design, targets));
        }
    }
    let train0 = train0.expect("at least one model");

    // -- the real components, all on the one sim clock: ONE router
    // collector serves every model name (requests carry their name)
    let mut server =
        BatchServer::spawn_router_with_clock(Arc::clone(&store), sc.batch, clock.clone());
    let submitter = server.submitter();
    let batches_now = |server: &BatchServer| -> u64 {
        server.counters().batches.load(Ordering::Relaxed)
    };
    let mut queue: Option<FitQueue> = sc.faults.iter().any(Fault::needs_queue).then(|| {
        FitQueue::with_clock(
            sc.fit_workers,
            sc.fit_capacity,
            Some(Arc::clone(&store)),
            clock.clone(),
        )
        .expect("scenario fit-queue params are valid")
    });

    // -- the event list: workload arrivals (ClientStall windows applied
    // as a pre-pass) merged with runtime faults, stably ordered by tick
    // (arrivals before faults at equal instants)
    let mut arrivals: Vec<Arrival> = sc.workload.generate(sc.seed);
    for fault in &sc.faults {
        if let Fault::ClientStall { at, dur } = *fault {
            let resume = at.saturating_add(dur);
            for a in arrivals.iter_mut() {
                if a.at >= at && a.at < resume {
                    a.at = resume; // delivered as one catch-up burst
                }
            }
        }
    }
    let runtime_faults: Vec<Fault> = sc
        .faults
        .iter()
        .filter(|f| !matches!(f, Fault::ClientStall { .. }))
        .cloned()
        .collect();
    let mut events: Vec<(Tick, Ev)> = arrivals
        .iter()
        .enumerate()
        .map(|(i, a)| (a.at, Ev::Arrive(i)))
        .chain(
            runtime_faults
                .iter()
                .enumerate()
                .map(|(k, f)| (f.at(), Ev::Fault(k))),
        )
        .collect();
    events.sort_by_key(|(t, _)| *t);

    // -- run the event loop
    let mut obs = Observed {
        latencies_us: Vec::with_capacity(arrivals.len()),
        victim_latencies_us: Vec::new(),
        responses: 0,
        failed_responses: 0,
        shutdown_responses: 0,
        overloaded_responses: 0,
        bit_checked: 0,
        max_version: 0,
        completed_jobs: 0,
        failed_jobs: 0,
        expired_jobs: 0,
        high_lead_jobs: None,
        swap_published: None,
        swap_visible_at: None,
        panic_batches: None,
        recovery_batches: None,
        cancelled_requests: 0,
        deadline_jobs: 0,
        deadline_met_jobs: 0,
        rebalance_loads_before: None,
        rebalance_moved: None,
    };
    let mut tickets: Vec<InFlight> = Vec::new();
    let mut pending_jobs: Vec<(JobId, JobKind)> = Vec::new();
    let mut requests = 0u64;
    let mut rejected_jobs = 0u64;
    let mut pending_panic_snapshot = false;
    let mut ei = 0usize;
    loop {
        sim.until_quiescent();
        if pending_panic_snapshot {
            obs.panic_batches = Some(batches_now(&server));
            pending_panic_snapshot = false;
        }
        // jobs before tickets: a hot-swap publish must be in the
        // version map before a response served by it is checked
        poll_jobs(queue.as_ref(), &mut pending_jobs, &mut obs, &store, &mut versions, &sim);
        drain_tickets(&mut tickets, &arrivals, sc.victim_model, &mut obs, &versions, &sim, || {
            batches_now(&server)
        });

        let next_event = events.get(ei).map(|(t, _)| *t);
        match (next_event, sim.next_deadline()) {
            (None, None) => break,
            // deadline-first at ties: the flush at `td` happens before
            // arrivals at the same instant (they join the next batch)
            (Some(te), Some(td)) if td <= te => sim.advance_to(td),
            (Some(te), _) => {
                if te > sim.now() {
                    sim.advance_to(te);
                    sim.until_quiescent();
                }
                while ei < events.len() && events[ei].0 <= sim.now() {
                    let (_, ev) = &events[ei];
                    ei += 1;
                    match ev {
                        Ev::Arrive(i) => {
                            let a = &arrivals[*i];
                            tickets.push(InFlight {
                                submitted: sim.now(),
                                arrival: *i,
                                ticket: submitter
                                    .submit_to(&model_name(a.model), a.request.clone()),
                            });
                            requests += 1;
                        }
                        Ev::Fault(k) => inject(
                            &runtime_faults[*k],
                            sc,
                            &train0,
                            queue.as_ref(),
                            &store,
                            sim.now(),
                            &mut tickets,
                            &mut pending_jobs,
                            &mut rejected_jobs,
                            &mut pending_panic_snapshot,
                            &mut obs,
                        )?,
                    }
                }
            }
            (None, Some(td)) => sim.advance_to(td),
        }
    }
    // events exhausted and nothing scheduled: one last observation pass
    poll_jobs(queue.as_ref(), &mut pending_jobs, &mut obs, &store, &mut versions, &sim);
    drain_tickets(&mut tickets, &arrivals, sc.victim_model, &mut obs, &versions, &sim, || {
        batches_now(&server)
    });
    assert!(
        pending_jobs.is_empty(),
        "{}: fit jobs still pending at quiescence",
        sc.name
    );
    let end = sim.now().max(sc.workload.horizon);

    // -- teardown (kicks + joins), then account anything shutdown flushed
    drop(submitter);
    let batches = batches_now(&server);
    let served: u64 = server.counters().requests.load(Ordering::Relaxed);
    server.shutdown();
    // after shutdown: the final flush has skipped any dropped rows
    let cancelled_rows = server.counters().cancelled.load(Ordering::Relaxed);
    if let Some(q) = queue.as_mut() {
        q.shutdown();
    }
    // rebalance observable: the hot shard's share of routed store
    // reads, before the rebalance instant vs after it
    let hot_share = |loads: &[u64]| -> Option<f64> {
        let total: u64 = loads.iter().sum();
        (total > 0).then(|| loads.iter().max().copied().unwrap_or(0) as f64 / total as f64)
    };
    let (hot_share_before, hot_share_after) = match &obs.rebalance_loads_before {
        Some(before) => {
            let after: Vec<u64> = store
                .shard_loads()
                .iter()
                .zip(before.iter())
                .map(|(total, b)| total.saturating_sub(*b))
                .collect();
            (hot_share(before), hot_share(&after))
        }
        None => (None, None),
    };
    for inflight in tickets {
        match inflight.ticket.poll() {
            Some(Err(ShotgunError::ServerShutdown)) => obs.shutdown_responses += 1,
            Some(Err(ShotgunError::Overloaded { .. })) => obs.overloaded_responses += 1,
            // undrained at quiescence = a bug surfaced
            Some(Ok(_)) | Some(Err(_)) | None => obs.failed_responses += 1,
        }
    }

    obs.latencies_us.sort_by(|a, b| a.total_cmp(b));
    obs.victim_latencies_us.sort_by(|a, b| a.total_cmp(b));
    let virtual_seconds = end as f64 * 1e-9;
    Ok(Outcome {
        name: sc.name.to_string(),
        requests,
        responses: obs.responses,
        failed_responses: obs.failed_responses,
        shutdown_responses: obs.shutdown_responses,
        overloaded_responses: obs.overloaded_responses,
        batches,
        mean_batch: if batches == 0 {
            0.0
        } else {
            served as f64 / batches as f64
        },
        virtual_seconds,
        throughput_rps: if virtual_seconds > 0.0 {
            obs.responses as f64 / virtual_seconds
        } else {
            0.0
        },
        p50_us: percentile(&obs.latencies_us, 0.50),
        p90_us: percentile(&obs.latencies_us, 0.90),
        p99_us: percentile(&obs.latencies_us, 0.99),
        max_us: obs.latencies_us.last().copied().unwrap_or(0.0),
        bit_identity_checked: obs.bit_checked,
        completed_jobs: obs.completed_jobs,
        failed_jobs: obs.failed_jobs,
        rejected_jobs,
        expired_jobs: obs.expired_jobs,
        high_lead_jobs: obs.high_lead_jobs.unwrap_or(0),
        swap_lag_us: match (obs.swap_published, obs.swap_visible_at) {
            (Some((published, _)), Some(visible)) => {
                Some(visible.saturating_sub(published) as f64 * 1e-3)
            }
            _ => None,
        },
        recovery_batches: obs.recovery_batches,
        max_version_served: obs.max_version,
        cancelled_requests: obs.cancelled_requests,
        cancelled_rows,
        victim_p99_us: sc
            .victim_model
            .map(|_| percentile(&obs.victim_latencies_us, 0.99)),
        deadline_jobs: obs.deadline_jobs,
        deadline_met_jobs: obs.deadline_met_jobs,
        rebalance_moved: obs.rebalance_moved,
        hot_share_before,
        hot_share_after,
    })
}

/// Inject one runtime fault (driver-side; see `Fault` docs).
#[allow(clippy::too_many_arguments)]
fn inject(
    fault: &Fault,
    sc: &Scenario,
    train0: &(Arc<Design>, Arc<Vec<f64>>),
    queue: Option<&FitQueue>,
    store: &ModelStore,
    now: Tick,
    tickets: &mut Vec<InFlight>,
    pending_jobs: &mut Vec<(JobId, JobKind)>,
    rejected_jobs: &mut u64,
    pending_panic_snapshot: &mut bool,
    obs: &mut Observed,
) -> Result<(), ShotgunError> {
    // driver-only faults first: they need no FitQueue
    match *fault {
        Fault::TicketDrop { count, .. } => {
            // drop the `count` OLDEST unresolved tickets (front of the
            // submission-ordered vec): each drop releases its admission
            // slot immediately and flags the pending row so the
            // collector skips it at flush
            let n = count.min(tickets.len());
            tickets.drain(..n); // dropping a ticket flags + releases it
            obs.cancelled_requests += n as u64;
            return Ok(());
        }
        Fault::Rebalance { .. } => {
            obs.rebalance_loads_before = Some(store.shard_loads());
            obs.rebalance_moved = Some(store.rebalance() as u64);
            return Ok(());
        }
        _ => {}
    }
    let queue = queue.expect("queue faults build a FitQueue");
    let base_job = |lam: f64| {
        FitJob::new(
            Arc::clone(&train0.0),
            Arc::clone(&train0.1),
            sc.loss,
            lam,
        )
        .solver_name(solver_for(sc.loss))
        .options(|o| {
            o.max_iters = 200_000;
            o.tol = 1e-6;
        })
    };
    match *fault {
        Fault::WorkerPanic { .. } => {
            let id = queue.submit(base_job(sc.train_lam).fault(FitFault::Panic))?;
            pending_jobs.push((id, JobKind::Panic));
            *pending_panic_snapshot = true;
        }
        Fault::HotSwap { lam, cost, .. } => {
            let id = queue.submit(
                base_job(lam)
                    .publish_as(model_name(0))
                    .fault(FitFault::SlowFit { cost }),
            )?;
            pending_jobs.push((id, JobKind::Swap));
        }
        Fault::QueueSaturation {
            jobs, wedge_cost, ..
        } => {
            // deferred submits + one kick: the whole burst lands in the
            // bounded channel before any worker wakes, so acceptance is
            // a function of capacity alone (see try_submit_deferred).
            // Wedges go first (FIFO → they occupy every worker), with
            // distinct costs so no two completions tie on the timeline.
            for w in 0..sc.fit_workers.max(1) {
                let cost = wedge_cost + w as Tick * 1_000_001;
                match queue
                    .try_submit_deferred(base_job(sc.train_lam).fault(FitFault::SlowFit { cost }))?
                {
                    Some(id) => pending_jobs.push((id, JobKind::Wedge)),
                    None => *rejected_jobs += 1,
                }
            }
            for _ in 0..jobs {
                match queue.try_submit_deferred(base_job(sc.train_lam))? {
                    Some(id) => pending_jobs.push((id, JobKind::Burst)),
                    None => *rejected_jobs += 1,
                }
            }
            queue.kick_workers();
        }
        Fault::PriorityBurst {
            batch_jobs,
            expired_jobs,
            fill_cost,
            ..
        } => {
            // the workers are already wedged (pair with a jobs-free
            // QueueSaturation an instant earlier), so the whole
            // inverted burst lands in the lanes before any worker
            // wakes. Submission order is deliberately worst-case —
            // doomed Normals, slow Batch fillers, High LAST — because
            // lane order, not arrival order, must decide who runs
            // first. Filler costs are staggered so no two completions
            // tie on the timeline.
            for _ in 0..expired_jobs {
                // lapses while the workers are still wedged → must
                // fail typed at dequeue, never run
                match queue
                    .try_submit_deferred(base_job(sc.train_lam).deadline_at(now + 1_000))?
                {
                    Some(id) => pending_jobs.push((id, JobKind::Expired)),
                    None => *rejected_jobs += 1,
                }
            }
            for k in 0..batch_jobs {
                let cost = fill_cost + k as Tick * 1_000_003;
                match queue.try_submit_deferred(
                    base_job(sc.train_lam)
                        .priority(JobPriority::Batch)
                        .fault(FitFault::SlowFit { cost }),
                )? {
                    Some(id) => pending_jobs.push((id, JobKind::BatchFiller)),
                    None => *rejected_jobs += 1,
                }
            }
            match queue
                .try_submit_deferred(base_job(sc.train_lam).priority(JobPriority::High))?
            {
                Some(id) => pending_jobs.push((id, JobKind::HighPri)),
                None => *rejected_jobs += 1,
            }
            queue.kick_workers();
        }
        Fault::DeadlineBurst { jobs, job_cost, .. } => {
            // wedge every worker so the whole dated burst lands in the
            // Normal lane before anyone pops. Wedges carry deadlines
            // just under the burst's earliest (they are dequeued at
            // `now`, so never expired) — under EDF a dated burst would
            // otherwise jump the dateless wedges. ONE wedge is short
            // (`job_cost`); the rest sit out the whole burst, with
            // staggered costs so no two completions tie.
            for w in 0..sc.fit_workers.max(1) {
                let cost = if w == 0 {
                    job_cost
                } else {
                    (jobs as Tick + 2) * job_cost + w as Tick * 1_000_001
                };
                match queue.try_submit_deferred(
                    base_job(sc.train_lam)
                        .deadline_at(now + 1 + w as Tick)
                        .fault(FitFault::SlowFit { cost }),
                )? {
                    Some(id) => pending_jobs.push((id, JobKind::Wedge)),
                    None => *rejected_jobs += 1,
                }
            }
            // the dated burst, submitted in REVERSE deadline order
            // (latest first): rank r (0 = earliest) is due at
            // now + job_cost*(r+2) and costs job_cost. The short-wedged
            // worker frees at now + job_cost and EDF-drains rank r at
            // now + job_cost*(r+1) — inside its deadline, every time.
            // FIFO would pop rank 0 LAST at now + job_cost*jobs and
            // expire it for any jobs >= 3.
            for r in (0..jobs).rev() {
                match queue.try_submit_deferred(
                    base_job(sc.train_lam)
                        .deadline_at(now + job_cost * (r as Tick + 2))
                        .fault(FitFault::SlowFit { cost: job_cost }),
                )? {
                    Some(id) => {
                        pending_jobs.push((id, JobKind::DeadlineJob));
                        obs.deadline_jobs += 1;
                    }
                    None => *rejected_jobs += 1,
                }
            }
            queue.kick_workers();
        }
        Fault::ClientStall { .. } => unreachable!("applied to the workload pre-pass"),
        Fault::TicketDrop { .. } | Fault::Rebalance { .. } => {
            unreachable!("driver-side faults handled above")
        }
    }
    Ok(())
}

/// Observe terminal fit jobs (at quiescence). A completed hot-swap
/// records its published version + instant; a panic job counts as a
/// typed failure.
fn poll_jobs(
    queue: Option<&FitQueue>,
    pending_jobs: &mut Vec<(JobId, JobKind)>,
    obs: &mut Observed,
    store: &ModelStore,
    versions: &mut HashMap<(usize, u64), Arc<Model>>,
    sim: &super::clock::SimClock,
) {
    let Some(queue) = queue else { return };
    // the priority-inversion observable, captured BEFORE the retain
    // pass mutates pending_jobs: the first poll that sees the High job
    // Done counts how many Batch fillers it beat (still non-terminal)
    if obs.high_lead_jobs.is_none() {
        let high_done = pending_jobs
            .iter()
            .any(|&(id, kind)| {
                kind == JobKind::HighPri
                    && matches!(queue.status(id), Some(JobState::Done(_)))
            });
        if high_done {
            let lead = pending_jobs
                .iter()
                .filter(|&&(id, kind)| {
                    kind == JobKind::BatchFiller
                        && !queue.status(id).is_some_and(|s| s.is_terminal())
                })
                .count() as u64;
            obs.high_lead_jobs = Some(lead);
        }
    }
    pending_jobs.retain(|&(id, kind)| {
        match queue.status(id) {
            Some(JobState::Done(_)) => {
                obs.completed_jobs += 1;
                if kind == JobKind::DeadlineJob {
                    // it ran, so the dequeue-time check passed — the
                    // deadline was met
                    obs.deadline_met_jobs += 1;
                }
                if kind == JobKind::Swap {
                    let rec = store.get(&model_name(0)).expect("published name");
                    versions.insert((0, rec.version), Arc::clone(&rec.model));
                    obs.swap_published = Some((sim.now(), rec.version));
                }
                let _ = queue.take(id);
                false
            }
            Some(JobState::Failed(err)) => {
                match kind {
                    JobKind::Panic => {
                        assert!(
                            matches!(err, ShotgunError::JobPanicked { .. }),
                            "panic job {id} failed as {err}"
                        );
                        obs.failed_jobs += 1;
                    }
                    JobKind::Expired => {
                        assert!(
                            matches!(err, ShotgunError::DeadlineExpired { .. }),
                            "doomed job {id} failed as {err}, not DeadlineExpired"
                        );
                        obs.expired_jobs += 1;
                    }
                    // a DeadlineBurst job that missed is a typed expiry
                    // (deadline_met_jobs then undercounts deadline_jobs
                    // — the scenario assertion catches it)
                    JobKind::DeadlineJob => {
                        assert!(
                            matches!(err, ShotgunError::DeadlineExpired { .. }),
                            "dated job {id} failed as {err}, not DeadlineExpired"
                        );
                        obs.expired_jobs += 1;
                    }
                    _ => panic!("job {id} ({kind:?}) failed unexpectedly: {err}"),
                }
                let _ = queue.take(id);
                false
            }
            _ => true,
        }
    });
}

/// Drain completed tickets (at quiescence): stamp virtual latencies,
/// check batch bit-identity per response, track swap visibility.
fn drain_tickets(
    tickets: &mut Vec<InFlight>,
    arrivals: &[Arrival],
    victim: Option<usize>,
    obs: &mut Observed,
    versions: &HashMap<(usize, u64), Arc<Model>>,
    sim: &super::clock::SimClock,
    batches_now: impl Fn() -> u64,
) {
    let now = sim.now();
    let mut still = Vec::with_capacity(tickets.len());
    for inflight in tickets.drain(..) {
        let Some(outcome) = inflight.ticket.poll() else {
            still.push(inflight);
            continue;
        };
        let arrival = &arrivals[inflight.arrival];
        match outcome {
            Err(ShotgunError::ServerShutdown) => obs.shutdown_responses += 1,
            Err(ShotgunError::Overloaded { .. }) => obs.overloaded_responses += 1,
            Err(_) => obs.failed_responses += 1,
            Ok(resp) => {
                obs.responses += 1;
                let latency_us = now.saturating_sub(inflight.submitted) as f64 * 1e-3;
                obs.latencies_us.push(latency_us);
                if victim == Some(arrival.model) {
                    obs.victim_latencies_us.push(latency_us);
                }
                obs.max_version = obs.max_version.max(resp.model_version);
                // bit-identity against sequential predict on the exact
                // version that served the batch
                let model = versions
                    .get(&(arrival.model, resp.model_version))
                    .unwrap_or_else(|| {
                        panic!(
                            "response for model {} served by unknown version {}",
                            arrival.model, resp.model_version
                        )
                    });
                let single = batch_design(std::slice::from_ref(&arrival.request), model.d())
                    .expect("request validated by the batch path");
                let score = model.decision_function(&single).expect("score")[0];
                let pred = model.predict(&single).expect("predict")[0];
                assert_eq!(
                    resp.score.to_bits(),
                    score.to_bits(),
                    "bit-identity: score diverged from sequential predict"
                );
                assert_eq!(
                    resp.prediction.to_bits(),
                    pred.to_bits(),
                    "bit-identity: prediction diverged from sequential predict"
                );
                if arrival.request.proba {
                    let proba = model.predict_proba(&single).expect("proba")[0];
                    assert_eq!(
                        resp.proba.map(f64::to_bits),
                        Some(proba.to_bits()),
                        "bit-identity: proba diverged from sequential predict"
                    );
                }
                obs.bit_checked += 1;
                // swap visibility: first response carrying the swapped
                // version (recovery metric rides on the same instant)
                if let Some((_, version)) = obs.swap_published {
                    if resp.model_version >= version && obs.swap_visible_at.is_none() {
                        obs.swap_visible_at = Some(now);
                        if let Some(panic_batches) = obs.panic_batches {
                            obs.recovery_batches =
                                Some(batches_now().saturating_sub(panic_batches));
                        }
                    }
                }
            }
        }
    }
    *tickets = still;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simserve::clock::SECOND;
    use crate::simserve::workload::RateCurve;
    use std::time::Duration;

    #[test]
    fn tiny_serve_only_scenario_runs_to_quiescence() {
        let sc = Scenario {
            name: "unit-tiny",
            workload: WorkloadSpec {
                curve: RateCurve::Constant { rps: 400.0 },
                horizon: SECOND / 4,
                models: 1,
                zipf_exponent: 0.0,
                d: 24,
                max_nnz: 5,
                proba_fraction: 0.0,
            },
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(800),
                ..Default::default()
            },
            faults: vec![],
            fit_workers: 1,
            fit_capacity: 4,
            store_shards: 2,
            seed: 5,
            loss: Loss::Squared,
            train_n: 40,
            train_lam: 0.2,
            victim_model: None,
        };
        let out = run(&sc).expect("scenario runs");
        assert!(out.requests > 0);
        assert_eq!(out.responses, out.requests);
        assert_eq!(out.failed_responses, 0);
        assert_eq!(out.shutdown_responses, 0);
        assert_eq!(out.overloaded_responses, 0);
        assert_eq!(out.bit_identity_checked, out.responses);
        assert!(out.batches > 0);
        assert!(out.p50_us <= out.p99_us && out.p99_us <= out.max_us);
        // the max_wait flush bounds every latency
        assert!(out.max_us <= 800.0 + 1e-9, "max latency {}", out.max_us);
        // deterministic: a second run is outcome-equal
        assert_eq!(out, run(&sc).expect("second run"));
    }
}
