//! Fault injection — scheduled disturbances a scenario drives through
//! the REAL serving components.
//!
//! Each [`Fault`] names an instant on the virtual timeline and a
//! disturbance the scenario runner injects when the event loop reaches
//! it. None of them bypass production code: a [`Fault::WorkerPanic`]
//! is a real `panic!` inside a real `FitQueue` worker (caught by the
//! queue's own `catch_unwind` machinery), a [`Fault::HotSwap`] is a
//! real refit job publishing into the live
//! [`ModelStore`](crate::api::serve::ModelStore), and
//! [`Fault::QueueSaturation`]
//! drives the bounded channel's typed overload rejections. The delayed
//! flush path (a partial batch sitting on the `max_wait` timer) needs
//! no explicit fault — any arrival gap longer than `max_wait` (the
//! `Bursty` off-phase, a [`Fault::ClientStall`] window) exercises it.
//!
//! The invariant every fault scenario must preserve: **batch
//! bit-identity**. Whatever breaks, every response that does come back
//! is bit-identical to a one-at-a-time `Model::predict` against the
//! model version that served it (the scenario runner checks each
//! response).

use super::clock::Tick;

/// One scheduled disturbance (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// At `at`, submit a fit job that panics inside its worker — the
    /// `catch_unwind` → `Failed(JobPanicked)` path. Serving must not
    /// notice, and the worker must survive to run later jobs.
    WorkerPanic { at: Tick },
    /// At `at`, submit a refit of model 0 at regularization `lam` that
    /// occupies its worker for `cost` virtual ticks, then publishes
    /// under the serving name — a hot swap landing mid-traffic. The
    /// runner measures the swap-visibility lag (publish → first
    /// response served by the new version).
    HotSwap { at: Tick, lam: f64, cost: Tick },
    /// At `at`, wedge every fit worker with a job costing `wedge_cost`
    /// ticks, then burst `jobs` non-blocking submissions into the
    /// bounded queue. With all workers wedged, acceptances are exactly
    /// the queue's free capacity and the rest are typed rejections —
    /// independent of worker count and machine speed.
    QueueSaturation {
        at: Tick,
        jobs: usize,
        wedge_cost: Tick,
    },
    /// A slow-reader stall: arrivals in `[at, at + dur)` are deferred
    /// and delivered as one burst at `at + dur` (an upstream client
    /// that stopped reading, then caught up). Applied to the workload
    /// stream before the event loop starts.
    ClientStall { at: Tick, dur: Tick },
    /// At `at`, with the workers already wedged (pair this with a
    /// jobs-free [`Fault::QueueSaturation`] a tick earlier), burst a
    /// priority-inversion workload through the lanes: `expired_jobs`
    /// Normal jobs whose deadlines lapse while the workers are wedged
    /// (they must fail typed at dequeue, never run), `batch_jobs`
    /// Batch-lane fillers costing `fill_cost` ticks each, and finally
    /// ONE High job submitted LAST. The High job must still finish
    /// before any Batch filler starts — the lane order beats the
    /// submission order.
    PriorityBurst {
        at: Tick,
        batch_jobs: usize,
        expired_jobs: usize,
        fill_cost: Tick,
    },
    /// At `at`, wedge every fit worker — ONE wedge costing `job_cost`
    /// ticks, the rest costing long enough to sit out the whole burst —
    /// then submit `jobs` Normal-lane jobs in REVERSE deadline order
    /// (latest deadline first), each costing `job_cost` and with
    /// deadline rank `r` (0 = earliest) due at `at + job_cost*(r+2)`.
    /// The one short-wedged worker frees at `at + job_cost` and drains
    /// the burst earliest-deadline-first, dequeuing rank `r` at
    /// `at + job_cost*(r+1)` — inside its deadline, so EVERY job meets
    /// its deadline regardless of worker count. Under the old FIFO
    /// lane the earliest deadline would be popped LAST and expire for
    /// any `jobs >= 3`.
    DeadlineBurst {
        at: Tick,
        jobs: usize,
        job_cost: Tick,
    },
    /// At `at`, the driver DROPS the `count` oldest unresolved predict
    /// tickets — clients that shed or abandoned their requests while
    /// the rows sat on the router's `max_wait` timer. The router must
    /// release their admission slots immediately and skip the rows at
    /// flush (no `decision_function` work for a reader that left).
    TicketDrop { at: Tick, count: usize },
    /// At `at`, the driver calls
    /// [`ModelStore::rebalance`](crate::api::serve::ModelStore::rebalance):
    /// per-name heat
    /// accumulated so far re-homes hot names off the loaded shard, and
    /// the runner snapshots per-shard load before/after to measure the
    /// occupancy gain.
    Rebalance { at: Tick },
}

impl Fault {
    /// When the fault fires (for `ClientStall`, when the stall begins).
    pub fn at(&self) -> Tick {
        match *self {
            Fault::WorkerPanic { at }
            | Fault::HotSwap { at, .. }
            | Fault::QueueSaturation { at, .. }
            | Fault::ClientStall { at, .. }
            | Fault::PriorityBurst { at, .. }
            | Fault::DeadlineBurst { at, .. }
            | Fault::TicketDrop { at, .. }
            | Fault::Rebalance { at } => at,
        }
    }

    /// Does this fault need a `FitQueue` in the scenario?
    pub fn needs_queue(&self) -> bool {
        !matches!(
            self,
            Fault::ClientStall { .. } | Fault::TicketDrop { .. } | Fault::Rebalance { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simserve::clock::SECOND;

    #[test]
    fn fault_instants_and_queue_needs() {
        let faults = [
            Fault::WorkerPanic { at: SECOND },
            Fault::HotSwap {
                at: 2 * SECOND,
                lam: 0.1,
                cost: 7,
            },
            Fault::QueueSaturation {
                at: 3 * SECOND,
                jobs: 10,
                wedge_cost: 11,
            },
            Fault::ClientStall {
                at: 4 * SECOND,
                dur: SECOND,
            },
            Fault::PriorityBurst {
                at: 5 * SECOND,
                batch_jobs: 4,
                expired_jobs: 2,
                fill_cost: 13,
            },
            Fault::DeadlineBurst {
                at: 6 * SECOND,
                jobs: 5,
                job_cost: 17,
            },
            Fault::TicketDrop {
                at: 7 * SECOND,
                count: 3,
            },
            Fault::Rebalance { at: 8 * SECOND },
        ];
        for (i, f) in faults.iter().enumerate() {
            assert_eq!(f.at(), (i as u64 + 1) * SECOND);
        }
        assert!(faults[..3].iter().all(Fault::needs_queue));
        assert!(!faults[3].needs_queue());
        assert!(faults[4].needs_queue());
        assert!(faults[5].needs_queue());
        assert!(!faults[6].needs_queue());
        assert!(!faults[7].needs_queue());
    }
}
