//! Seeded workload generation — the million-user traffic shapes as
//! deterministic arrival streams.
//!
//! A [`WorkloadSpec`] fully determines a stream of [`Arrival`]s (same
//! spec + seed → bit-identical stream): arrival instants from a
//! non-homogeneous Poisson process over a [`RateCurve`] (Lewis-Shedler
//! thinning against the curve's peak rate), per-arrival model routing
//! from a [`Zipf`] popularity law (the heavy-tailed "one hot model,
//! many cold ones" shape), and request content drawn the same way as
//! `testkit::requests` (sparse rows, seeded).
//!
//! The three curve families cover the scenario axes the ROADMAP names:
//! * [`RateCurve::Constant`] — the baseline closed-form load;
//! * [`RateCurve::Diurnal`] — a smooth day/night cosine between a base
//!   and a peak rate;
//! * [`RateCurve::Bursty`] — an on/off square wave (thundering herds,
//!   delayed-flush windows in the gaps).
//!
//! `tests/simserve.rs` holds the property tests: bit-identical streams
//! per seed, arrival counts integrating to
//! [`RateCurve::expected_total`], and the Zipf tail matching its
//! exponent.

use super::clock::Tick;
use crate::api::serve::PredictRequest;
use crate::util::rng::Rng;

/// Requests-per-second as a function of virtual time.
#[derive(Clone, Debug, PartialEq)]
pub enum RateCurve {
    /// λ(t) = `rps`.
    Constant { rps: f64 },
    /// Smooth diurnal curve: λ(t) = base + (peak − base) · (1 − cos(2πt/period)) / 2
    /// — starts at `base_rps`, peaks mid-`period`, returns to base.
    Diurnal {
        base_rps: f64,
        peak_rps: f64,
        period: Tick,
    },
    /// Square wave: `on_rps` for `on` ticks, then `off_rps` for `off`
    /// ticks, repeating.
    Bursty {
        on_rps: f64,
        off_rps: f64,
        on: Tick,
        off: Tick,
    },
}

impl RateCurve {
    /// Instantaneous rate at `t`, requests per second.
    pub fn rate_at(&self, t: Tick) -> f64 {
        match *self {
            RateCurve::Constant { rps } => rps,
            RateCurve::Diurnal {
                base_rps,
                peak_rps,
                period,
            } => {
                let phase = t as f64 / period.max(1) as f64;
                base_rps
                    + (peak_rps - base_rps) * (1.0 - (2.0 * std::f64::consts::PI * phase).cos())
                        / 2.0
            }
            RateCurve::Bursty {
                on_rps,
                off_rps,
                on,
                off,
            } => {
                let cycle = on.saturating_add(off).max(1);
                if t % cycle < on {
                    on_rps
                } else {
                    off_rps
                }
            }
        }
    }

    /// The curve's maximum rate (the thinning envelope).
    pub fn peak(&self) -> f64 {
        match *self {
            RateCurve::Constant { rps } => rps,
            RateCurve::Diurnal {
                base_rps, peak_rps, ..
            } => base_rps.max(peak_rps),
            RateCurve::Bursty {
                on_rps, off_rps, ..
            } => on_rps.max(off_rps),
        }
    }

    /// ∫λ dt over `[0, horizon)` — the expected arrival count (closed
    /// form per family; the integration property test compares actual
    /// counts against this within Poisson tolerance).
    pub fn expected_total(&self, horizon: Tick) -> f64 {
        let h = horizon as f64 * 1e-9; // seconds
        match *self {
            RateCurve::Constant { rps } => rps * h,
            RateCurve::Diurnal {
                base_rps,
                peak_rps,
                period,
            } => {
                // ∫ (1 - cos(2πt/T))/2 dt = (h - T sin(2πh/T)/(2π)) / 2
                let t_s = period.max(1) as f64 * 1e-9;
                let two_pi = 2.0 * std::f64::consts::PI;
                let shaped = (h - t_s * (two_pi * h / t_s).sin() / two_pi) / 2.0;
                base_rps * h + (peak_rps - base_rps) * shaped
            }
            RateCurve::Bursty {
                on_rps,
                off_rps,
                on,
                off,
            } => {
                let cycle = on.saturating_add(off).max(1);
                let full = horizon / cycle;
                let rem = horizon % cycle;
                let on_ticks = full * on + rem.min(on);
                let off_ticks = horizon - on_ticks;
                on_rps * (on_ticks as f64 * 1e-9) + off_rps * (off_ticks as f64 * 1e-9)
            }
        }
    }
}

/// Arrival instants over `[0, horizon)` for a non-homogeneous Poisson
/// process with rate `curve` — Lewis-Shedler thinning: draw candidate
/// gaps from the peak-rate homogeneous process, keep each candidate
/// with probability `rate_at(t) / peak`. Deterministic in `rng`.
pub fn arrivals(curve: &RateCurve, horizon: Tick, rng: &mut Rng) -> Vec<Tick> {
    let peak = curve.peak();
    if !peak.is_finite() || peak <= 0.0 || horizon == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let horizon_s = horizon as f64 * 1e-9;
    let mut t_s = 0.0f64;
    loop {
        // exponential gap at the envelope rate; uniform() is in [0, 1)
        // so 1-u is in (0, 1] and the log is finite
        t_s += -(1.0 - rng.uniform()).ln() / peak;
        if t_s >= horizon_s {
            return out;
        }
        let tick = (t_s * 1e9) as Tick;
        if rng.uniform() * peak < curve.rate_at(tick) {
            out.push(tick.min(horizon - 1));
        }
    }
}

/// Zipf popularity over `n` items: item `k` has weight `1/(k+1)^s`.
/// `s = 0` is uniform; larger `s` concentrates mass on item 0 (the hot
/// model).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, exponent: f64) -> Zipf {
        let n = n.max(1);
        let mut cdf: Vec<f64> = (0..n)
            .map(|k| ((k + 1) as f64).powf(-exponent))
            .collect();
        let total: f64 = cdf.iter().sum();
        let mut acc = 0.0;
        for w in cdf.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        // guard against rounding: the last bucket must cover u -> 1.0
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Zipf { cdf }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Probability of item `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        let lo = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - lo
    }

    /// Draw one item index.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf
            .partition_point(|&c| c <= u)
            .min(self.cdf.len() - 1)
    }
}

/// One generated request: when it arrives, which model it targets, and
/// its feature row.
#[derive(Clone, Debug, PartialEq)]
pub struct Arrival {
    /// Arrival instant, virtual ticks.
    pub at: Tick,
    /// Target model index (`0 .. WorkloadSpec::models`).
    pub model: usize,
    /// The request body.
    pub request: PredictRequest,
}

/// Everything that determines a workload (same spec + seed →
/// bit-identical [`generate`] output).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Arrival-rate shape.
    pub curve: RateCurve,
    /// Stream length in virtual ticks.
    pub horizon: Tick,
    /// Number of served models requests route across.
    pub models: usize,
    /// Zipf exponent for per-model popularity (0 = uniform).
    pub zipf_exponent: f64,
    /// Feature dimension requests index into (the models' `d`).
    pub d: usize,
    /// Max nonzero features per request (uniform in `[1, max_nnz]`).
    pub max_nnz: usize,
    /// Fraction of requests asking for a probability read-out (keep 0
    /// unless the served models are logistic).
    pub proba_fraction: f64,
}

impl WorkloadSpec {
    /// Generate the full arrival stream from `seed` (see type docs).
    pub fn generate(&self, seed: u64) -> Vec<Arrival> {
        assert!(self.d > 0, "workload needs d >= 1");
        let mut rng = Rng::new(seed);
        let times = arrivals(&self.curve, self.horizon, &mut rng);
        let zipf = Zipf::new(self.models.max(1), self.zipf_exponent);
        let max_nnz = self.max_nnz.clamp(1, self.d);
        times
            .into_iter()
            .map(|at| {
                let model = zipf.draw(&mut rng);
                // same row shape as testkit::requests::stream
                let k = 1 + rng.below(max_nnz);
                let mut idx = rng.sample_without_replacement(self.d, k);
                idx.sort_unstable();
                let features = idx.into_iter().map(|j| (j as u32, rng.normal())).collect();
                let proba =
                    self.proba_fraction > 0.0 && rng.bernoulli(self.proba_fraction);
                Arrival {
                    at,
                    model,
                    request: PredictRequest { features, proba },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simserve::clock::SECOND;

    fn spec(curve: RateCurve) -> WorkloadSpec {
        WorkloadSpec {
            curve,
            horizon: 2 * SECOND,
            models: 4,
            zipf_exponent: 1.0,
            d: 32,
            max_nnz: 6,
            proba_fraction: 0.0,
        }
    }

    #[test]
    fn same_seed_same_stream() {
        let s = spec(RateCurve::Diurnal {
            base_rps: 200.0,
            peak_rps: 1000.0,
            period: SECOND,
        });
        let a = s.generate(9);
        assert_eq!(a, s.generate(9), "same seed, same stream");
        assert_ne!(a, s.generate(10), "different seed, different stream");
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at, "arrivals are time-ordered");
        }
        for arr in &a {
            assert!(arr.at < s.horizon);
            assert!(arr.model < s.models);
            assert!(!arr.request.features.is_empty());
            assert!(arr.request.features.len() <= 6);
        }
    }

    #[test]
    fn constant_curve_count_matches_expectation() {
        let curve = RateCurve::Constant { rps: 500.0 };
        let mut rng = Rng::new(3);
        let n = arrivals(&curve, 4 * SECOND, &mut rng).len() as f64;
        let want = curve.expected_total(4 * SECOND);
        assert_eq!(want, 2000.0);
        // Poisson: 6 sigma around the mean is a ~1e-9 false-positive
        assert!((n - want).abs() < 6.0 * want.sqrt() + 1.0, "n = {n}");
    }

    #[test]
    fn bursty_rate_and_integral_are_piecewise() {
        let curve = RateCurve::Bursty {
            on_rps: 900.0,
            off_rps: 100.0,
            on: SECOND / 4,
            off: (3 * SECOND) / 4,
        };
        assert_eq!(curve.rate_at(0), 900.0);
        assert_eq!(curve.rate_at(SECOND / 2), 100.0);
        assert_eq!(curve.rate_at(SECOND), 900.0);
        // one full cycle: 900 * 0.25s + 100 * 0.75s
        let total = curve.expected_total(SECOND);
        assert!((total - 300.0).abs() < 1e-9, "total = {total}");
    }

    #[test]
    fn zipf_is_normalized_and_head_heavy() {
        let z = Zipf::new(10, 1.2);
        assert_eq!(z.len(), 10);
        let sum: f64 = (0..10).map(|k| z.pmf(k)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(z.pmf(0) > z.pmf(1) && z.pmf(1) > z.pmf(9));
        // exponent 0 is uniform
        let u = Zipf::new(8, 0.0);
        for k in 0..8 {
            assert!((u.pmf(k) - 0.125).abs() < 1e-12);
        }
        // draws hit every bucket and never go out of range
        let mut rng = Rng::new(1);
        let mut seen = [0usize; 10];
        for _ in 0..5_000 {
            seen[z.draw(&mut rng)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 0));
        assert!(seen[0] > seen[9]);
    }
}
