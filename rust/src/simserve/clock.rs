//! The `Clock` abstraction the serving plane runs on — wall time in
//! production, discrete virtual time under simulation.
//!
//! Every time-dependent wait in `api::serve` (the collector's
//! `max_wait` flush timer, the fit workers' idle wait) goes through a
//! [`Clock`] instead of `Instant::now()`/`recv_timeout`. With
//! [`Clock::wall`] (the default everywhere) the behavior is exactly the
//! old one: real threads, real timeouts. With [`Clock::sim`] the same
//! REAL component threads park on a virtual timeline that only the
//! simulation driver advances — the Calimero sync_sim pattern (real
//! components + simulated clock), not mocks.
//!
//! # The eventcount protocol (no lost wakeups)
//!
//! A waiter that checks a channel and then sleeps can miss a message
//! sent in between. The clock closes that race with a generation
//! counter: the waiter reads a token ([`park_token`](Clock::park_token))
//! **before** checking its work source, and [`park`](Clock::park)
//! returns immediately if any [`kick`](Clock::kick) landed after the
//! token was taken. Producers kick after every enqueue, so the
//! check-then-park loop
//!
//! ```text
//! loop {
//!     let tok = clock.park_token();
//!     match source.try_recv() {
//!         Ok(item) => ...,
//!         Err(Empty) => clock.park(tok, deadline),
//!         Err(Disconnected) => return,
//!     }
//! }
//! ```
//!
//! never sleeps through a wakeup, on either clock.
//!
//! # Quiescence (sim only)
//!
//! Component threads register with the clock
//! ([`register`](Clock::register), RAII deregistration). The driver's
//! [`SimClock::until_quiescent`] blocks until **every** registered
//! thread is parked with no reason to wake — no pending kick, no
//! expired deadline. At quiescence nothing can happen until the driver
//! advances time ([`SimClock::advance_to`], typically to
//! [`SimClock::next_deadline`]), so batch composition, flush order, and
//! every latency stamp are functions of the scenario alone, independent
//! of machine speed and OS scheduling.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant};

/// Virtual or wall nanoseconds since the clock's epoch.
pub type Tick = u64;

/// One virtual (or wall) second, in [`Tick`]s.
pub const SECOND: Tick = 1_000_000_000;

/// `Duration` → ticks, saturating (a `Duration` can exceed u64 ns).
pub fn dur_ticks(d: Duration) -> Tick {
    u64::try_from(d.as_nanos()).unwrap_or(Tick::MAX)
}

/// The time source the serving components run on (see module docs).
/// Cheap to clone — clones share the underlying clock.
#[derive(Clone)]
pub enum Clock {
    /// Real time over [`Instant`]; waits are condvar timeouts.
    Wall(Arc<WallClock>),
    /// Discrete virtual time advanced explicitly by a driver.
    Sim(Arc<SimClock>),
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Clock::Wall(_) => write!(f, "Clock::Wall({} ns)", self.now()),
            Clock::Sim(_) => write!(f, "Clock::Sim({} ns)", self.now()),
        }
    }
}

impl Clock {
    /// A fresh wall clock (epoch = now). The production default.
    pub fn wall() -> Clock {
        Clock::Wall(Arc::new(WallClock::new()))
    }

    /// A fresh simulated clock at tick 0.
    pub fn sim() -> Clock {
        Clock::Sim(Arc::new(SimClock::new()))
    }

    /// The sim driver handle, if this is a sim clock.
    pub fn sim_handle(&self) -> Option<&Arc<SimClock>> {
        match self {
            Clock::Wall(_) => None,
            Clock::Sim(s) => Some(s),
        }
    }

    /// Nanoseconds since the clock's epoch.
    pub fn now(&self) -> Tick {
        match self {
            Clock::Wall(w) => w.now(),
            Clock::Sim(s) => s.now(),
        }
    }

    /// Take a wakeup token. Must be read BEFORE checking the work
    /// source the subsequent [`park`](Self::park) waits for.
    pub fn park_token(&self) -> u64 {
        match self {
            Clock::Wall(w) => w.generation(),
            Clock::Sim(s) => s.generation(),
        }
    }

    /// Sleep until a [`kick`](Self::kick) lands after `token` was
    /// taken, or until `deadline` (ticks) passes, whichever is first.
    /// Returns immediately if either already happened. Spurious returns
    /// are allowed — callers loop.
    pub fn park(&self, token: u64, deadline: Option<Tick>) {
        match self {
            Clock::Wall(w) => w.park(token, deadline),
            Clock::Sim(s) => s.park(token, deadline),
        }
    }

    /// Wake every parked thread (they re-check their work sources).
    pub fn kick(&self) {
        match self {
            Clock::Wall(w) => w.kick(),
            Clock::Sim(s) => s.kick(),
        }
    }

    /// Register the calling component thread for quiescence accounting
    /// (no-op on a wall clock). Call on the spawning thread and move
    /// the guard into the component thread; registration lasts until
    /// the guard drops.
    pub fn register(&self) -> ClockGuard {
        match self {
            Clock::Wall(_) => ClockGuard { sim: None },
            Clock::Sim(s) => {
                s.register();
                ClockGuard {
                    sim: Some(Arc::clone(s)),
                }
            }
        }
    }

    /// Let `cost` ticks pass on this clock: a real sleep on the wall
    /// clock, a parked wait for the driver to advance past the deadline
    /// on the sim clock. Used by fault injection to model work that
    /// takes time (e.g. a slow fit occupying its worker).
    pub fn sleep(&self, cost: Tick) {
        match self {
            Clock::Wall(_) => std::thread::sleep(Duration::from_nanos(cost)),
            Clock::Sim(s) => {
                let deadline = s.now().saturating_add(cost);
                loop {
                    let tok = s.generation();
                    if s.now() >= deadline {
                        return;
                    }
                    s.park(tok, Some(deadline));
                }
            }
        }
    }
}

/// RAII registration of a component thread on a sim clock (see
/// [`Clock::register`]). Dropping deregisters and re-checks quiescence.
pub struct ClockGuard {
    sim: Option<Arc<SimClock>>,
}

impl Drop for ClockGuard {
    fn drop(&mut self) {
        if let Some(sim) = self.sim.take() {
            sim.deregister();
        }
    }
}

/// Real time: `Instant` for `now`, a generation-counted condvar for
/// park/kick (see the module docs' eventcount protocol).
pub struct WallClock {
    epoch: OnceLock<Instant>,
    gen: Mutex<u64>,
    wake: Condvar,
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl WallClock {
    pub fn new() -> WallClock {
        let epoch = OnceLock::new();
        let _ = epoch.set(Instant::now());
        WallClock {
            epoch,
            gen: Mutex::new(0),
            wake: Condvar::new(),
        }
    }

    fn epoch(&self) -> Instant {
        *self.epoch.get_or_init(Instant::now)
    }

    fn now(&self) -> Tick {
        u64::try_from(self.epoch().elapsed().as_nanos()).unwrap_or(Tick::MAX)
    }

    fn generation(&self) -> u64 {
        *self.gen.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn park(&self, token: u64, deadline: Option<Tick>) {
        let mut gen = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if *gen != token {
                return;
            }
            match deadline {
                None => {
                    gen = self
                        .wake
                        .wait(gen)
                        .unwrap_or_else(PoisonError::into_inner);
                }
                Some(d) => {
                    let now = self.now();
                    if now >= d {
                        return;
                    }
                    let (g, timeout) = self
                        .wake
                        .wait_timeout(gen, Duration::from_nanos(d - now))
                        .unwrap_or_else(PoisonError::into_inner);
                    gen = g;
                    if timeout.timed_out() && self.now() >= d {
                        return;
                    }
                }
            }
        }
    }

    fn kick(&self) {
        let mut gen = self.gen.lock().unwrap_or_else(PoisonError::into_inner);
        *gen = gen.wrapping_add(1);
        self.wake.notify_all();
    }
}

/// One parked component thread, as the sim driver sees it.
struct Sleeper {
    /// The generation its park token was taken at — a later kick means
    /// it is about to wake.
    token: u64,
    /// Its wake deadline, if any.
    deadline: Option<Tick>,
}

struct SimState {
    now: Tick,
    gen: u64,
    registered: usize,
    /// Parked threads by sleeper id.
    parked: HashMap<u64, Sleeper>,
    next_sleeper: u64,
}

impl SimState {
    /// True when nothing can happen until the driver advances time:
    /// every registered thread is parked with a current token and an
    /// unexpired (or absent) deadline.
    fn quiescent(&self) -> bool {
        self.parked.len() == self.registered
            && self
                .parked
                .values()
                .all(|s| s.token == self.gen && s.deadline.is_none_or(|d| self.now < d))
    }
}

/// Discrete virtual time plus the driver API (see the module docs).
///
/// Component threads use it through [`Clock::Sim`]; the scenario driver
/// holds the `Arc<SimClock>` directly and alternates
/// [`until_quiescent`](Self::until_quiescent) →
/// [`next_deadline`](Self::next_deadline) →
/// [`advance_to`](Self::advance_to).
pub struct SimClock {
    state: Mutex<SimState>,
    /// Component threads wait here (woken by kick/advance).
    sleepers: Condvar,
    /// The driver waits here for quiescence.
    driver: Condvar,
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock {
            state: Mutex::new(SimState {
                now: 0,
                gen: 0,
                registered: 0,
                parked: HashMap::new(),
                next_sleeper: 0,
            }),
            sleepers: Condvar::new(),
            driver: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SimState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Current virtual time.
    pub fn now(&self) -> Tick {
        self.lock().now
    }

    fn generation(&self) -> u64 {
        self.lock().gen
    }

    fn register(&self) {
        self.lock().registered += 1;
    }

    fn deregister(&self) {
        let mut st = self.lock();
        st.registered = st.registered.saturating_sub(1);
        // one fewer thread to wait for — quiescence may hold now
        self.driver.notify_all();
    }

    fn park(&self, token: u64, deadline: Option<Tick>) {
        let mut st = self.lock();
        if st.token_stale(token) || deadline.is_some_and(|d| st.now >= d) {
            return;
        }
        let id = st.next_sleeper;
        st.next_sleeper += 1;
        st.parked.insert(id, Sleeper { token, deadline });
        // this thread may have completed quiescence
        self.driver.notify_all();
        while st.parked[&id].token == st.gen
            && st.parked[&id].deadline.is_none_or(|d| st.now < d)
        {
            st = self
                .sleepers
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
        st.parked.remove(&id);
    }

    /// Wake all sleepers (a producer enqueued work).
    pub fn kick(&self) {
        let mut st = self.lock();
        st.gen = st.gen.wrapping_add(1);
        self.sleepers.notify_all();
    }

    // ---- driver API -------------------------------------------------

    /// Block until every registered component thread is parked with
    /// nothing to do (see [`SimState::quiescent`]). With no registered
    /// threads this returns immediately.
    pub fn until_quiescent(&self) {
        let mut st = self.lock();
        while !st.quiescent() {
            st = self
                .driver
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// The earliest wake deadline among parked threads — the next
    /// instant anything is scheduled to happen. Meaningful at
    /// quiescence.
    pub fn next_deadline(&self) -> Option<Tick> {
        self.lock().parked.values().filter_map(|s| s.deadline).min()
    }

    /// Move virtual time forward to `t` (monotonic; earlier `t` is a
    /// no-op on `now`) and wake sleepers so expired deadlines fire.
    pub fn advance_to(&self, t: Tick) {
        let mut st = self.lock();
        st.now = st.now.max(t);
        st.gen = st.gen.wrapping_add(1);
        self.sleepers.notify_all();
    }

    /// Registered component threads (tests/diagnostics).
    pub fn registered(&self) -> usize {
        self.lock().registered
    }
}

impl SimState {
    fn token_stale(&self, token: u64) -> bool {
        self.gen != token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::mpsc;

    #[test]
    fn wall_clock_is_monotonic_and_parks_until_deadline() {
        let clock = Clock::wall();
        let t0 = clock.now();
        let tok = clock.park_token();
        // 2ms deadline: park returns at/after it even with no kick
        clock.park(tok, Some(t0 + 2_000_000));
        assert!(clock.now() >= t0 + 2_000_000);
        // a pre-expired deadline returns immediately
        clock.park(clock.park_token(), Some(0));
    }

    #[test]
    fn wall_kick_wakes_a_parked_thread() {
        let clock = Clock::wall();
        let clock2 = clock.clone();
        let (tx, rx) = mpsc::channel();
        let h = std::thread::spawn(move || {
            let tok = clock2.park_token();
            tx.send(()).unwrap();
            // no deadline: only the kick can end this park
            clock2.park(tok, None);
        });
        rx.recv().unwrap();
        // kick until the parked thread exits (covers the window where
        // the kick lands before the park does — the token makes that
        // park return immediately)
        while !h.is_finished() {
            clock.kick();
            std::thread::yield_now();
        }
        h.join().unwrap();
    }

    #[test]
    fn sim_time_is_driver_controlled() {
        let clock = Clock::sim();
        assert_eq!(clock.now(), 0);
        let sim = clock.sim_handle().unwrap();
        sim.advance_to(5 * SECOND);
        assert_eq!(clock.now(), 5 * SECOND);
        sim.advance_to(SECOND); // monotonic: no going back
        assert_eq!(clock.now(), 5 * SECOND);
    }

    #[test]
    fn sim_quiescence_and_deadline_stepping() {
        let clock = Clock::sim();
        let sim = Arc::clone(clock.sim_handle().unwrap());
        let woke_at = Arc::new(AtomicU64::new(u64::MAX));
        let guard = clock.register();
        let h = {
            let clock = clock.clone();
            let woke_at = Arc::clone(&woke_at);
            std::thread::spawn(move || {
                let _guard = guard;
                // sleep 3 virtual seconds: parks until the driver
                // advances past the deadline
                clock.sleep(3 * SECOND);
                woke_at.store(clock.now(), Ordering::SeqCst);
            })
        };
        sim.until_quiescent();
        // the sleeper's deadline is the only scheduled instant
        assert_eq!(sim.next_deadline(), Some(3 * SECOND));
        assert_eq!(woke_at.load(Ordering::SeqCst), u64::MAX);
        sim.advance_to(3 * SECOND);
        h.join().unwrap();
        assert_eq!(woke_at.load(Ordering::SeqCst), 3 * SECOND);
        // thread deregistered on exit; quiescence is trivial again
        sim.until_quiescent();
        assert_eq!(sim.registered(), 0);
        assert_eq!(sim.next_deadline(), None);
    }

    #[test]
    fn sim_kick_beats_deadline_and_tokens_prevent_lost_wakeups() {
        let clock = Clock::sim();
        let sim = Arc::clone(clock.sim_handle().unwrap());
        // token taken, THEN a kick lands, THEN park: must not sleep
        let tok = clock.park_token();
        clock.kick();
        clock.park(tok, None); // returns immediately (stale token)

        // a registered thread parked without deadline wakes on kick
        let guard = clock.register();
        let (tx, rx) = mpsc::channel();
        let h = {
            let clock = clock.clone();
            std::thread::spawn(move || {
                let _guard = guard;
                loop {
                    let tok = clock.park_token();
                    if rx.try_recv().is_ok() {
                        return clock.now();
                    }
                    clock.park(tok, None);
                }
            })
        };
        sim.until_quiescent();
        sim.advance_to(7);
        tx.send(()).unwrap();
        clock.kick();
        assert_eq!(h.join().unwrap(), 7);
    }
}
