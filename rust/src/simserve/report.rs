//! The named scenario suite behind `repro sim`, and the
//! `BENCH_simserve.json` report it emits.
//!
//! [`suite`] defines the canonical scenarios (one [`Scenario`] each,
//! same names in smoke and full mode — smoke shrinks horizons/rates so
//! CI finishes in seconds). [`run_suite`] executes them (optionally
//! filtered to one name) and [`SuiteReport::to_bench_json`] renders the
//! machine-readable document `scripts/check_bench.py` gates:
//!
//! * `derived.batching_latency_p99_ratio` — p99 virtual latency of the
//!   `max_batch = 64` baseline over the `max_batch = 8` one (same
//!   workload, same seed): what deeper coalescing costs in tail latency.
//! * `derived.fault_recovery_rounds` — batches flushed between the
//!   worker-panic injection and the recovery hot-swap becoming visible.
//! * `derived.swap_visibility_lag_us` — hot-swap publish → first
//!   response served by the new version, virtual microseconds.
//! * `derived.overload_shed_requests` — requests the admission gate
//!   shed with typed `Overloaded` in the `overload-shedding` scenario.
//! * `derived.priority_queue_lead_jobs` — Batch fillers the
//!   `priority-inversion` High job beat to completion (must equal the
//!   burst size).
//! * `derived.fairness_p99_ratio` — the non-flooding tenant's p99 under
//!   `FirstSeen` over its p99 under `DeficitRr`, from the
//!   `flooding-tenant-*` A/B pair (same workload, same seed; > 1 means
//!   deficit round-robin protected the victim).
//! * `derived.edf_deadline_hit_rate` — fraction of the
//!   `edf-beats-fifo` dated jobs that completed inside their deadlines
//!   (1.0 when EDF works; plain FIFO would expire the earliest).
//! * `derived.cancelled_flush_rows` — pending rows the router skipped
//!   at flush in `dropped-ticket-no-work` because their ticket was
//!   dropped (cancellation propagation: shed clients cost no
//!   `decision_function` work).
//! * `derived.rebalance_p99_gain` — hottest shard's share of routed
//!   store reads before over after the `hot-shard-rebalance` move
//!   (> 1 means rebalancing actually spread the heat).
//!
//! Every number in the report is virtual-time deterministic: same
//! suite + seed → byte-identical JSON, on any machine.

use super::clock::{Tick, SECOND};
use super::faults::Fault;
use super::scenario::{run, Outcome, Scenario};
use super::workload::{RateCurve, WorkloadSpec};
use crate::api::serve::{BatchConfig, FlushFairness};
use crate::api::ShotgunError;
use crate::objective::Loss;
use std::time::Duration;

/// One virtual millisecond.
const MS: Tick = SECOND / 1000;

/// The scenario names the acceptance gate requires (a subset of
/// [`suite`]; `tests/simserve.rs` checks coverage).
pub const REQUIRED_SCENARIOS: [&str; 16] = [
    "baseline-batch8",
    "baseline-batch64",
    "diurnal",
    "bursty",
    "zipf-hot-model",
    "worker-panic-recovery",
    "hot-swap-under-load",
    "multi-model-routing",
    "shard-swap-under-load",
    "priority-inversion",
    "overload-shedding",
    "flooding-tenant-firstseen",
    "flooding-tenant-fairness",
    "edf-beats-fifo",
    "dropped-ticket-no-work",
    "hot-shard-rebalance",
];

/// The canonical named scenarios (see module docs). `smoke` shrinks
/// horizons 10x and rates 2.5x; names and structure are identical in
/// both modes.
pub fn suite(smoke: bool, seed: u64) -> Vec<Scenario> {
    let stretch: u64 = if smoke { 1 } else { 10 };
    let rate: f64 = if smoke { 1.0 } else { 2.5 };
    let train_n = if smoke { 60 } else { 120 };
    let ms = |x: u64| x * stretch * MS;
    let sd = |k: u64| seed.wrapping_mul(1000).wrapping_add(k);
    let batch = |max_batch: usize, max_wait_us: u64| BatchConfig {
        max_batch,
        max_wait: Duration::from_micros(max_wait_us),
        ..BatchConfig::default()
    };
    let workload = |curve: RateCurve, horizon: Tick, models: usize, zipf: f64, proba: f64| {
        WorkloadSpec {
            curve,
            horizon,
            models,
            zipf_exponent: zipf,
            d: 64,
            max_nnz: 8,
            proba_fraction: proba,
        }
    };

    let mut out = Vec::new();
    // -- baseline batching sweep: ONE workload, two batch policies; the
    // p99 ratio between them is the headline derived metric
    let baseline = workload(
        RateCurve::Constant { rps: 8_000.0 * rate },
        ms(250),
        1,
        0.0,
        0.0,
    );
    for (name, max_batch) in [("baseline-batch8", 8), ("baseline-batch64", 64)] {
        out.push(Scenario {
            name,
            workload: baseline.clone(),
            batch: batch(max_batch, 20_000),
            faults: vec![],
            fit_workers: 2,
            fit_capacity: 8,
            store_shards: 4,
            seed: sd(1), // same seed: same arrivals, different batching
            loss: Loss::Squared,
            train_n,
            train_lam: 0.1,
            victim_model: None,
        });
    }
    // -- diurnal day/night curve over two logistic models (proba mix)
    out.push(Scenario {
        name: "diurnal",
        workload: workload(
            RateCurve::Diurnal {
                base_rps: 500.0 * rate,
                peak_rps: 3_000.0 * rate,
                period: ms(100),
            },
            ms(200),
            2,
            0.8,
            0.25,
        ),
        batch: batch(32, 2_000),
        faults: vec![],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(2),
        loss: Loss::Logistic,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- bursty on/off square wave; the off-phase gaps exercise the
    // delayed (max_wait timer) flush path
    out.push(Scenario {
        name: "bursty",
        workload: workload(
            RateCurve::Bursty {
                on_rps: 4_000.0 * rate,
                off_rps: 50.0 * rate,
                on: ms(50),
                off: ms(150),
            },
            ms(400),
            1,
            0.0,
            0.0,
        ),
        batch: batch(16, 2_000),
        faults: vec![],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(3),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- Zipf heavy tail: one hot model, five cold ones
    out.push(Scenario {
        name: "zipf-hot-model",
        workload: workload(
            RateCurve::Constant { rps: 2_000.0 * rate },
            ms(200),
            6,
            1.1,
            0.2,
        ),
        batch: batch(16, 2_000),
        faults: vec![],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(4),
        loss: Loss::Logistic,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- worker panic mid-fit, then a recovery hot-swap: proves the
    // worker survives and counts the batches served while degraded
    let h = ms(200);
    out.push(Scenario {
        name: "worker-panic-recovery",
        workload: workload(RateCurve::Constant { rps: 2_000.0 * rate }, h, 1, 0.0, 0.0),
        batch: batch(16, 2_000),
        faults: vec![
            Fault::WorkerPanic { at: h / 6 },
            Fault::HotSwap {
                at: h / 3,
                lam: 0.08,
                // odd cost: completion never ties a Poisson-derived
                // flush deadline, keeping the timeline unambiguous
                cost: 37_000_001,
            },
        ],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(5),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- hot swap under peak load: swap-visibility lag is the metric
    out.push(Scenario {
        name: "hot-swap-under-load",
        workload: workload(RateCurve::Constant { rps: 3_000.0 * rate }, h, 1, 0.0, 0.0),
        batch: batch(32, 2_000),
        faults: vec![Fault::HotSwap {
            at: h / 3,
            lam: 0.12,
            cost: 23_000_003,
        }],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(6),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- queue saturation: all workers wedged, burst overflows the
    // bounded queue; rejections = burst - free capacity, exactly
    out.push(Scenario {
        name: "queue-saturation",
        workload: workload(
            RateCurve::Constant { rps: 500.0 * rate },
            ms(100),
            1,
            0.0,
            0.0,
        ),
        batch: batch(8, 2_000),
        faults: vec![Fault::QueueSaturation {
            at: ms(25),
            jobs: 6,
            wedge_cost: 11_000_009,
        }],
        fit_workers: 2,
        fit_capacity: 4, // 2 wedges + 2 burst accepted -> 4 rejected
        store_shards: 4,
        seed: sd(7),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- slow-reader stall: a mid-stream arrival gap, then a catch-up
    // burst (delayed flushes on the way in, deep batches on the way out)
    out.push(Scenario {
        name: "client-stall",
        workload: workload(
            RateCurve::Constant { rps: 2_000.0 * rate },
            ms(150),
            1,
            0.0,
            0.0,
        ),
        batch: batch(16, 2_000),
        faults: vec![Fault::ClientStall {
            at: ms(50),
            dur: ms(50),
        }],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(8),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- multi-tenant routing: four models through ONE router collector
    // (Zipf-skewed name mix), sharded store; every response must still
    // be bit-identical on its own (name, version)
    out.push(Scenario {
        name: "multi-model-routing",
        workload: workload(
            RateCurve::Constant { rps: 3_000.0 * rate },
            ms(200),
            4,
            1.0,
            0.0,
        ),
        batch: batch(16, 2_000),
        faults: vec![],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(9),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- hot swap on one tenant of a sharded multi-tenant store: the
    // swap lands on m0's shard while traffic keeps flowing to the rest
    out.push(Scenario {
        name: "shard-swap-under-load",
        workload: workload(
            RateCurve::Constant { rps: 2_000.0 * rate },
            h,
            3,
            0.5,
            0.0,
        ),
        batch: batch(16, 2_000),
        faults: vec![Fault::HotSwap {
            at: h / 3,
            lam: 0.09,
            cost: 29_000_009,
        }],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(10),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- priority inversion: wedge the workers (jobs-free saturation),
    // then burst doomed-deadline Normals + slow Batch fillers + one
    // High job submitted LAST; the High job must still win the lanes.
    // The burst fires a fixed 1ms after the wedge (the wedge holds for
    // 9ms in both smoke and full mode, so the workers are still busy)
    out.push(Scenario {
        name: "priority-inversion",
        workload: workload(
            RateCurve::Constant { rps: 500.0 * rate },
            ms(100),
            1,
            0.0,
            0.0,
        ),
        batch: batch(8, 2_000),
        faults: vec![
            Fault::QueueSaturation {
                at: ms(30),
                jobs: 0, // wedge-only: no burst fillers of its own
                wedge_cost: 9_000_007,
            },
            Fault::PriorityBurst {
                at: ms(30) + MS,
                batch_jobs: 4,
                expired_jobs: 2,
                fill_cost: 3_000_001,
            },
        ],
        fit_workers: 2,
        fit_capacity: 16,
        store_shards: 4,
        seed: sd(11),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- overload shedding: a tight max_in_flight gate under heavy
    // constant load; sheds must be typed Overloaded, never hangs
    out.push(Scenario {
        name: "overload-shedding",
        workload: workload(
            RateCurve::Constant { rps: 8_000.0 * rate },
            ms(100),
            1,
            0.0,
            0.0,
        ),
        batch: BatchConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(2_000),
            max_in_flight: 8,
            ..BatchConfig::default()
        },
        faults: vec![],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(12),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- flooding tenant A/B: one tenant floods the shared router while
    // a victim tenant trickles; same workload + seed, two fairness
    // policies. A non-zero flush_cost makes flushes occupy the
    // collector (capacity 8 rows / ~1.7ms < arrival rate), so a backlog
    // forms and the flush policy decides who waits. The victim's p99
    // ratio between the two runs is the headline fairness metric.
    for (name, fairness) in [
        ("flooding-tenant-firstseen", FlushFairness::FirstSeen),
        (
            "flooding-tenant-fairness",
            FlushFairness::DeficitRr { quantum: 2 },
        ),
    ] {
        out.push(Scenario {
            name,
            workload: workload(
                RateCurve::Constant { rps: 6_000.0 * rate },
                ms(60),
                2,
                3.0, // zipf 3.0 over 2 models: ~8/9 flood, ~1/9 victim
                0.0,
            ),
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(2_000),
                fairness,
                flush_cost: Duration::from_micros(1_667),
                ..BatchConfig::default()
            },
            faults: vec![],
            fit_workers: 2,
            fit_capacity: 8,
            store_shards: 4,
            seed: sd(13), // same seed: same arrivals, different fairness
            loss: Loss::Squared,
            train_n,
            train_lam: 0.1,
            victim_model: Some(1),
        });
    }
    // -- EDF within a lane: wedge the workers, then burst dated Normal
    // jobs in REVERSE deadline order. Earliest-deadline-first dequeue
    // meets every deadline at any worker count; the old FIFO lane would
    // expire the earliest-due job (see Fault::DeadlineBurst docs).
    out.push(Scenario {
        name: "edf-beats-fifo",
        workload: workload(
            RateCurve::Constant { rps: 500.0 * rate },
            ms(100),
            1,
            0.0,
            0.0,
        ),
        batch: batch(8, 2_000),
        faults: vec![Fault::DeadlineBurst {
            at: ms(30),
            jobs: 4,
            job_cost: 5_000_003,
        }],
        fit_workers: 2,
        fit_capacity: 16,
        store_shards: 4,
        seed: sd(14),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- cancellation propagation: slow trickle onto a deep max_wait
    // timer (rows pool on the partial-batch deadline), then the driver
    // drops the 3 oldest in-flight tickets. The router must release
    // their admission slots at once and skip exactly those rows at
    // flush — shed clients cost no decision_function work.
    out.push(Scenario {
        name: "dropped-ticket-no-work",
        workload: workload(
            RateCurve::Constant { rps: 400.0 * rate },
            ms(100),
            1,
            0.0,
            0.0,
        ),
        batch: batch(64, 20_000),
        faults: vec![Fault::TicketDrop {
            at: ms(50),
            count: 3,
        }],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(15),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    // -- hot-shard rebalancing: six tenants whose names all hash onto
    // one shard of four (the fnv1a vnode ring clusters short names —
    // see ROADMAP), Zipf-skewed traffic, then a mid-horizon rebalance.
    // The hottest shard's share of routed reads must drop after the
    // overlay re-homes hot names.
    out.push(Scenario {
        name: "hot-shard-rebalance",
        workload: workload(
            RateCurve::Constant { rps: 3_000.0 * rate },
            ms(100),
            6,
            0.7,
            0.0,
        ),
        batch: batch(16, 2_000),
        faults: vec![Fault::Rebalance { at: ms(50) }],
        fit_workers: 2,
        fit_capacity: 8,
        store_shards: 4,
        seed: sd(16),
        loss: Loss::Squared,
        train_n,
        train_lam: 0.1,
        victim_model: None,
    });
    out
}

/// Outcomes of a (possibly filtered) suite run.
#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub smoke: bool,
    pub seed: u64,
    pub outcomes: Vec<Outcome>,
}

/// Run the named suite. `filter = Some(name)` runs just that scenario
/// (unknown names yield an empty report — the CLI turns that into an
/// error with the valid names).
pub fn run_suite(
    smoke: bool,
    seed: u64,
    filter: Option<&str>,
) -> Result<SuiteReport, ShotgunError> {
    let mut outcomes = Vec::new();
    for sc in suite(smoke, seed) {
        if filter.is_some_and(|f| f != sc.name) {
            continue;
        }
        outcomes.push(run(&sc)?);
    }
    Ok(SuiteReport {
        smoke,
        seed,
        outcomes,
    })
}

/// One human-readable line per scenario (the CLI's table body).
pub fn report_line(o: &Outcome) -> String {
    let mut line = format!(
        "{:<22} {:>7} req -> {:>7} ok | {:>6} batches (mean {:>5.1}) | us p50 {:>8.1} p99 {:>9.1} | {:.3} vs",
        o.name,
        o.requests,
        o.responses,
        o.batches,
        o.mean_batch,
        o.p50_us,
        o.p99_us,
        o.virtual_seconds,
    );
    if let Some(lag) = o.swap_lag_us {
        line.push_str(&format!(" | swap lag {lag:.1}us"));
    }
    if let Some(rounds) = o.recovery_batches {
        line.push_str(&format!(" | recovery {rounds} rounds"));
    }
    if o.rejected_jobs > 0 {
        line.push_str(&format!(" | {} jobs rejected", o.rejected_jobs));
    }
    if o.overloaded_responses > 0 {
        line.push_str(&format!(" | {} shed", o.overloaded_responses));
    }
    if o.expired_jobs > 0 {
        line.push_str(&format!(" | {} expired", o.expired_jobs));
    }
    if o.high_lead_jobs > 0 {
        line.push_str(&format!(" | high led {}", o.high_lead_jobs));
    }
    if o.cancelled_requests > 0 {
        line.push_str(&format!(
            " | {} dropped ({} rows skipped)",
            o.cancelled_requests, o.cancelled_rows
        ));
    }
    if o.deadline_jobs > 0 {
        line.push_str(&format!(
            " | deadlines {}/{}",
            o.deadline_met_jobs, o.deadline_jobs
        ));
    }
    if let Some(p99) = o.victim_p99_us {
        line.push_str(&format!(" | victim p99 {p99:.1}us"));
    }
    if let Some(moved) = o.rebalance_moved {
        let (b, a) = (
            o.hot_share_before.unwrap_or(0.0),
            o.hot_share_after.unwrap_or(0.0),
        );
        line.push_str(&format!(
            " | rebalance {moved} moved, hot {:.0}% -> {:.0}%",
            b * 100.0,
            a * 100.0
        ));
    }
    line
}

impl SuiteReport {
    /// The outcome of scenario `name`, if it ran.
    pub fn outcome(&self, name: &str) -> Option<&Outcome> {
        self.outcomes.iter().find(|o| o.name == name)
    }

    /// The `BENCH_simserve.json` document. Requires the full unfiltered
    /// suite (the derived metrics read specific named scenarios).
    pub fn to_bench_json(&self) -> String {
        let need = |name: &str| -> &Outcome {
            self.outcome(name)
                .unwrap_or_else(|| panic!("bench JSON needs scenario {name:?}; run unfiltered"))
        };
        let b8 = need("baseline-batch8");
        let b64 = need("baseline-batch64");
        let panic_recovery = need("worker-panic-recovery");
        let swap = need("hot-swap-under-load");
        let inversion = need("priority-inversion");
        let shedding = need("overload-shedding");
        let firstseen = need("flooding-tenant-firstseen");
        let drr = need("flooding-tenant-fairness");
        let edf = need("edf-beats-fifo");
        let dropped = need("dropped-ticket-no-work");
        let rebalance = need("hot-shard-rebalance");
        let ratio = b64.p99_us / b8.p99_us.max(1e-12);
        let recovery_rounds = panic_recovery
            .recovery_batches
            .expect("worker-panic-recovery measures recovery") as f64;
        let swap_lag = swap
            .swap_lag_us
            .expect("hot-swap-under-load measures swap lag");
        let fairness_ratio = firstseen
            .victim_p99_us
            .expect("flooding-tenant-firstseen tracks the victim")
            / drr
                .victim_p99_us
                .expect("flooding-tenant-fairness tracks the victim")
                .max(1e-12);
        let edf_hit_rate =
            edf.deadline_met_jobs as f64 / (edf.deadline_jobs as f64).max(1.0);
        let rebalance_gain = rebalance
            .hot_share_before
            .expect("hot-shard-rebalance snapshots shard loads")
            / rebalance
                .hot_share_after
                .expect("hot-shard-rebalance snapshots shard loads")
                .max(1e-12);
        let requests_total: u64 = self.outcomes.iter().map(|o| o.requests).sum();

        let mut scenarios = String::new();
        for (i, o) in self.outcomes.iter().enumerate() {
            if i > 0 {
                scenarios.push_str(",\n");
            }
            let mut extras = String::new();
            if let Some(lag) = o.swap_lag_us {
                extras.push_str(&format!(", \"swap_lag_us\": {lag:.3}"));
            }
            if let Some(rounds) = o.recovery_batches {
                extras.push_str(&format!(", \"recovery_batches\": {rounds}"));
            }
            if let Some(p99) = o.victim_p99_us {
                extras.push_str(&format!(", \"victim_p99_us\": {p99:.3}"));
            }
            if let Some(moved) = o.rebalance_moved {
                extras.push_str(&format!(", \"rebalance_moved\": {moved}"));
            }
            if let (Some(b), Some(a)) = (o.hot_share_before, o.hot_share_after) {
                extras.push_str(&format!(
                    ", \"hot_share_before\": {b:.6}, \"hot_share_after\": {a:.6}"
                ));
            }
            scenarios.push_str(&format!(
                "    {{\"name\": \"{}\", \"requests\": {}, \"responses\": {}, \
                 \"failed_responses\": {}, \"shutdown_responses\": {}, \
                 \"overloaded_responses\": {}, \"batches\": {}, \"mean_batch\": {:.3}, \
                 \"virtual_seconds\": {:.6}, \"throughput_rps\": {:.3}, \
                 \"latency_us\": {{\"p50\": {:.3}, \"p90\": {:.3}, \"p99\": {:.3}, \"max\": {:.3}}}, \
                 \"bit_identity_checked\": {}, \"completed_jobs\": {}, \"failed_jobs\": {}, \
                 \"rejected_jobs\": {}, \"expired_jobs\": {}, \"high_lead_jobs\": {}, \
                 \"cancelled_requests\": {}, \"cancelled_rows\": {}, \
                 \"deadline_jobs\": {}, \"deadline_met_jobs\": {}, \
                 \"max_version_served\": {}{}}}",
                o.name,
                o.requests,
                o.responses,
                o.failed_responses,
                o.shutdown_responses,
                o.overloaded_responses,
                o.batches,
                o.mean_batch,
                o.virtual_seconds,
                o.throughput_rps,
                o.p50_us,
                o.p90_us,
                o.p99_us,
                o.max_us,
                o.bit_identity_checked,
                o.completed_jobs,
                o.failed_jobs,
                o.rejected_jobs,
                o.expired_jobs,
                o.high_lead_jobs,
                o.cancelled_requests,
                o.cancelled_rows,
                o.deadline_jobs,
                o.deadline_met_jobs,
                o.max_version_served,
                extras
            ));
        }
        format!(
            "{{\n  \"bench\": \"simserve\",\n  \"mode\": \"{}\",\n  \"seed\": {},\n  \
             \"config\": {{\"scenarios\": {}, \"virtual_time\": true}},\n  \
             \"scenarios\": [\n{}\n  ],\n  \"derived\": {{\n    \
             \"batching_latency_p99_ratio\": {:.9e},\n    \
             \"fault_recovery_rounds\": {:.1},\n    \
             \"swap_visibility_lag_us\": {:.3},\n    \
             \"overload_shed_requests\": {},\n    \
             \"priority_queue_lead_jobs\": {},\n    \
             \"fairness_p99_ratio\": {:.9e},\n    \
             \"edf_deadline_hit_rate\": {:.6},\n    \
             \"cancelled_flush_rows\": {},\n    \
             \"rebalance_p99_gain\": {:.9e},\n    \
             \"sim_scenarios\": {},\n    \
             \"sim_requests_total\": {}\n  }}\n}}\n",
            if self.smoke { "smoke" } else { "full" },
            self.seed,
            self.outcomes.len(),
            scenarios,
            ratio,
            recovery_rounds,
            swap_lag,
            shedding.overloaded_responses,
            inversion.high_lead_jobs,
            fairness_ratio,
            edf_hit_rate,
            dropped.cancelled_rows,
            rebalance_gain,
            self.outcomes.len(),
            requests_total
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn suite_names_are_stable_and_cover_the_required_set() {
        for smoke in [true, false] {
            let scs = suite(smoke, 7);
            assert!(scs.len() >= 8, "suite has {} scenarios", scs.len());
            let names: Vec<&str> = scs.iter().map(|s| s.name).collect();
            for required in REQUIRED_SCENARIOS {
                assert!(names.contains(&required), "missing scenario {required}");
            }
            // names unique
            let mut dedup = names.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), names.len(), "duplicate scenario names");
            // the baseline pair shares one workload + seed
            let b8 = scs.iter().find(|s| s.name == "baseline-batch8").unwrap();
            let b64 = scs.iter().find(|s| s.name == "baseline-batch64").unwrap();
            assert_eq!(b8.seed, b64.seed);
            assert_eq!(b8.workload.horizon, b64.workload.horizon);
            assert_ne!(b8.batch.max_batch, b64.batch.max_batch);
            // the fairness A/B pair differs ONLY in the flush policy
            let fs = scs
                .iter()
                .find(|s| s.name == "flooding-tenant-firstseen")
                .unwrap();
            let dr = scs
                .iter()
                .find(|s| s.name == "flooding-tenant-fairness")
                .unwrap();
            assert_eq!(fs.seed, dr.seed);
            assert_eq!(fs.workload.horizon, dr.workload.horizon);
            assert_eq!(fs.batch.max_batch, dr.batch.max_batch);
            assert_eq!(fs.batch.flush_cost, dr.batch.flush_cost);
            assert_ne!(fs.batch.fairness, dr.batch.fairness);
            assert_eq!(fs.victim_model, Some(1));
            assert_eq!(dr.victim_model, Some(1));
        }
    }

    #[test]
    fn bench_json_is_valid_and_derived_fields_are_finite() {
        let outcome = |name: &str, p99: f64| Outcome {
            name: name.to_string(),
            requests: 100,
            responses: 100,
            failed_responses: 0,
            shutdown_responses: 0,
            overloaded_responses: 0,
            batches: 20,
            mean_batch: 5.0,
            virtual_seconds: 0.25,
            throughput_rps: 400.0,
            p50_us: p99 / 2.0,
            p90_us: p99 * 0.9,
            p99_us: p99,
            max_us: p99 * 1.1,
            bit_identity_checked: 100,
            completed_jobs: 0,
            failed_jobs: 0,
            rejected_jobs: 0,
            expired_jobs: 0,
            high_lead_jobs: 0,
            swap_lag_us: None,
            recovery_batches: None,
            max_version_served: 1,
            cancelled_requests: 0,
            cancelled_rows: 0,
            victim_p99_us: None,
            deadline_jobs: 0,
            deadline_met_jobs: 0,
            rebalance_moved: None,
            hot_share_before: None,
            hot_share_after: None,
        };
        let mut panic_recovery = outcome("worker-panic-recovery", 900.0);
        panic_recovery.failed_jobs = 1;
        panic_recovery.completed_jobs = 1;
        panic_recovery.recovery_batches = Some(7);
        panic_recovery.swap_lag_us = Some(1500.0);
        let mut swap = outcome("hot-swap-under-load", 1100.0);
        swap.swap_lag_us = Some(2100.5);
        swap.max_version_served = 2;
        let mut inversion = outcome("priority-inversion", 700.0);
        inversion.completed_jobs = 7;
        inversion.expired_jobs = 2;
        inversion.high_lead_jobs = 4;
        let mut shedding = outcome("overload-shedding", 600.0);
        shedding.responses = 80;
        shedding.overloaded_responses = 20;
        let mut firstseen = outcome("flooding-tenant-firstseen", 5000.0);
        firstseen.victim_p99_us = Some(4000.0);
        let mut drr = outcome("flooding-tenant-fairness", 5000.0);
        drr.victim_p99_us = Some(500.0);
        let mut edf = outcome("edf-beats-fifo", 700.0);
        edf.deadline_jobs = 4;
        edf.deadline_met_jobs = 4;
        edf.completed_jobs = 6;
        let mut dropped = outcome("dropped-ticket-no-work", 20000.0);
        dropped.responses = 97;
        dropped.cancelled_requests = 3;
        dropped.cancelled_rows = 3;
        let mut rebalance = outcome("hot-shard-rebalance", 800.0);
        rebalance.rebalance_moved = Some(4);
        rebalance.hot_share_before = Some(1.0);
        rebalance.hot_share_after = Some(0.4);
        let report = SuiteReport {
            smoke: true,
            seed: 42,
            outcomes: vec![
                outcome("baseline-batch8", 1000.0),
                outcome("baseline-batch64", 8000.0),
                panic_recovery,
                swap,
                inversion,
                shedding,
                firstseen,
                drr,
                edf,
                dropped,
                rebalance,
            ],
        };
        let json = report.to_bench_json();
        let doc = Json::parse(&json).expect("valid JSON");
        assert_eq!(
            doc.get("bench").and_then(|b| b.as_str().map(String::from)),
            Some("simserve".into())
        );
        let derived = doc.get("derived").expect("derived section");
        let f = |k: &str| derived.get(k).and_then(|v| v.as_f64()).expect(k);
        assert!((f("batching_latency_p99_ratio") - 8.0).abs() < 1e-9);
        assert_eq!(f("fault_recovery_rounds"), 7.0);
        assert!((f("swap_visibility_lag_us") - 2100.5).abs() < 1e-9);
        assert_eq!(f("overload_shed_requests"), 20.0);
        assert_eq!(f("priority_queue_lead_jobs"), 4.0);
        assert!((f("fairness_p99_ratio") - 8.0).abs() < 1e-9);
        assert!((f("edf_deadline_hit_rate") - 1.0).abs() < 1e-12);
        assert_eq!(f("cancelled_flush_rows"), 3.0);
        assert!((f("rebalance_p99_gain") - 2.5).abs() < 1e-9);
        assert_eq!(f("sim_scenarios"), 11.0);
        assert_eq!(f("sim_requests_total"), 1100.0);
        // per-scenario entries parse too
        let entries = doc.get("scenarios").and_then(Json::as_arr).expect("array");
        assert_eq!(entries.len(), 11);
        // a single-line human report renders the optional fields
        let line = report_line(&report.outcomes[3]);
        assert!(line.contains("hot-swap-under-load") && line.contains("swap lag"));
        let line = report_line(&report.outcomes[4]);
        assert!(line.contains("2 expired") && line.contains("high led 4"));
        let line = report_line(&report.outcomes[5]);
        assert!(line.contains("20 shed"));
        let line = report_line(&report.outcomes[7]);
        assert!(line.contains("victim p99 500.0us"));
        let line = report_line(&report.outcomes[8]);
        assert!(line.contains("deadlines 4/4"));
        let line = report_line(&report.outcomes[9]);
        assert!(line.contains("3 dropped (3 rows skipped)"));
        let line = report_line(&report.outcomes[10]);
        assert!(line.contains("rebalance 4 moved, hot 100% -> 40%"));
    }
}
