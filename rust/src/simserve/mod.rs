//! `simserve` — a deterministic traffic & fault simulator for the
//! serving plane.
//!
//! The serving subsystem ([`api::serve`](crate::api::serve)) is real
//! threads, real channels, real timers — which makes its interesting
//! behaviors (batch composition under bursty load, hot swaps landing
//! mid-traffic, worker panics, queue saturation) timing-dependent and
//! unreproducible under test. This module removes the wall clock from
//! that equation while keeping everything else real:
//!
//! * [`clock`] — the [`Clock`](clock::Clock) abstraction:
//!   [`WallClock`](clock::WallClock) for production (the default
//!   everywhere), [`SimClock`](clock::SimClock) for discrete virtual
//!   time with quiescence detection. `BatchServer`, `FitQueue`, and the
//!   replay harness all run on it — under a sim clock the REAL
//!   collector and worker threads park on a virtual timeline only the
//!   driver advances (the sync-simulation pattern: real components,
//!   simulated time — not mocks).
//! * [`workload`] — seeded traffic generators: constant / diurnal /
//!   bursty [`RateCurve`](workload::RateCurve)s driving a
//!   non-homogeneous Poisson arrival process, Zipf heavy-tailed
//!   per-model popularity, deterministic request content. Same spec +
//!   seed → bit-identical stream.
//! * [`faults`] — scheduled disturbances injected through production
//!   code paths: worker panic mid-fit, hot swap under load, bounded
//!   queue saturation, slow-reader stalls, reverse-order deadline
//!   bursts (EDF vs FIFO), driver-side ticket drops (cancellation
//!   propagation), and store rebalancing.
//! * [`scenario`] — the event-loop runner: drive a named scenario to
//!   quiescence, emitting a typed [`Outcome`](scenario::Outcome)
//!   (throughput, virtual latency percentiles, fault counters,
//!   swap-visibility lag, victim-tenant p99, deadline hit counts,
//!   rebalance load shares) while checking every response bit-for-bit
//!   against sequential predict.
//! * [`report`] — the canonical scenario [`suite`](report::suite) and
//!   the `BENCH_simserve.json` document behind `repro sim`.
//!
//! The determinism claim, precisely: an [`Outcome`] is a pure function
//! of its [`Scenario`](scenario::Scenario) — independent of machine
//! speed, OS scheduling, and fit-queue worker count. `tests/simserve.rs`
//! enforces run-to-run and cross-worker-count equality of the whole
//! outcome struct, latencies included.

pub mod clock;
pub mod faults;
pub mod report;
pub mod scenario;
pub mod workload;

pub use clock::{Clock, SimClock, Tick, WallClock, SECOND};
pub use faults::Fault;
pub use report::{run_suite, suite, SuiteReport, REQUIRED_SCENARIOS};
pub use scenario::{Outcome, Scenario};
pub use workload::{Arrival, RateCurve, WorkloadSpec, Zipf};
