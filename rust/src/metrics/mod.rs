//! Metrics: convergence traces, wall-clock timing, and the bench harness
//! that replaces criterion in this offline environment.

pub mod harness;

use std::time::Instant;

/// One sampled point of a solver run.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    /// Coordinate updates (or sample updates for SGD) performed so far.
    pub updates: u64,
    /// Outer iterations (rounds for Shotgun, epochs for SGD).
    pub iters: u64,
    /// Wall-clock seconds since solve start.
    pub seconds: f64,
    /// Objective F(x).
    pub objective: f64,
    /// Non-zeros in x.
    pub nnz: usize,
    /// Optional auxiliary metric (test error for logistic experiments).
    pub aux: f64,
}

/// Convergence trace of one solver run; every solver records one.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// Simulated-time seconds per point (memory-wall model), parallel to
    /// `points` when the simulator is enabled.
    pub sim_seconds: Vec<f64>,
}

impl Trace {
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn last_objective(&self) -> Option<f64> {
        self.points.last().map(|p| p.objective)
    }

    /// First wall-clock time at which the objective came within
    /// `rel_tol` of `f_star` (the paper's convergence-time metric:
    /// "first time within 0.5% of the optimal objective").
    pub fn time_to_tolerance(&self, f_star: f64, rel_tol: f64) -> Option<f64> {
        let thresh = threshold(f_star, rel_tol);
        self.points
            .iter()
            .find(|p| p.objective <= thresh)
            .map(|p| p.seconds)
    }

    /// First iteration count within tolerance (Fig. 2 / Fig. 5 metric).
    pub fn iters_to_tolerance(&self, f_star: f64, rel_tol: f64) -> Option<u64> {
        let thresh = threshold(f_star, rel_tol);
        self.points
            .iter()
            .find(|p| p.objective <= thresh)
            .map(|p| p.iters)
    }

    /// Objectives are recorded non-increasingly for descent methods; used
    /// by property tests.
    pub fn is_monotone_nonincreasing(&self, slack: f64) -> bool {
        self.points
            .windows(2)
            .all(|w| w[1].objective <= w[0].objective + slack)
    }

    /// CSV dump: header + one row per point.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("updates,iters,seconds,objective,nnz,aux\n");
        for p in &self.points {
            s.push_str(&format!(
                "{},{},{:.6},{:.10e},{},{:.6}\n",
                p.updates, p.iters, p.seconds, p.objective, p.nnz, p.aux
            ));
        }
        s
    }
}

/// `f_star`-relative convergence threshold; robust to `f_star ~ 0`.
pub fn threshold(f_star: f64, rel_tol: f64) -> f64 {
    f_star + rel_tol * f_star.abs().max(1e-12)
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with(objs: &[f64]) -> Trace {
        let mut t = Trace::default();
        for (i, &o) in objs.iter().enumerate() {
            t.push(TracePoint {
                updates: i as u64 * 10,
                iters: i as u64,
                seconds: i as f64 * 0.5,
                objective: o,
                nnz: i,
                aux: 0.0,
            });
        }
        t
    }

    #[test]
    fn tolerance_queries() {
        let t = trace_with(&[10.0, 5.0, 2.0, 1.01, 1.001]);
        // f* = 1.0, tol 0.5% -> threshold 1.005
        assert_eq!(t.iters_to_tolerance(1.0, 0.005), Some(4));
        assert_eq!(t.time_to_tolerance(1.0, 0.005), Some(2.0));
        assert_eq!(t.iters_to_tolerance(1.0, 0.05), Some(3));
        assert_eq!(t.iters_to_tolerance(0.0, 0.005), None);
    }

    #[test]
    fn monotonicity_check() {
        assert!(trace_with(&[3.0, 2.0, 2.0, 1.0]).is_monotone_nonincreasing(0.0));
        assert!(!trace_with(&[3.0, 2.0, 2.5]).is_monotone_nonincreasing(0.0));
        assert!(trace_with(&[3.0, 2.0, 2.0001]).is_monotone_nonincreasing(0.001));
    }

    #[test]
    fn csv_shape() {
        let csv = trace_with(&[1.0, 0.5]).to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("updates,"));
    }

    #[test]
    fn threshold_near_zero() {
        assert!(threshold(0.0, 0.005) > 0.0);
        assert!((threshold(100.0, 0.005) - 100.5).abs() < 1e-9);
    }
}
