//! Micro/macro benchmark harness (criterion substitute — criterion is not
//! in the vendored crate set). Warms up, runs timed samples, reports
//! median/mean/stddev, and writes results as JSON lines for the
//! experiment reports.

use crate::util::mean_std;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<48} median {:>12} mean {:>12} ± {:>10}  ({} samples)",
            self.name,
            fmt_secs(self.median_s),
            fmt_secs(self.mean_s),
            fmt_secs(self.std_s),
            self.samples
        )
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":{},\"samples\":{},\"mean_s\":{:.9},\"median_s\":{:.9},\"std_s\":{:.9},\"min_s\":{:.9},\"max_s\":{:.9}}}",
            crate::util::json::escape(&self.name),
            self.samples,
            self.mean_s,
            self.median_s,
            self.std_s,
            self.min_s,
            self.max_s
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `samples` timed runs.
/// The closure must do its full unit of work per call; return a value to
/// defeat dead-code elimination (it is black-boxed here).
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &times)
}

/// Time-budgeted variant: keeps sampling until `budget_s` elapses
/// (at least `min_samples`).
pub fn bench_for<T>(
    name: &str,
    budget_s: f64,
    min_samples: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    black_box(f()); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_samples || start.elapsed().as_secs_f64() < budget_s {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
        if times.len() > 100_000 {
            break;
        }
    }
    summarize(name, &times)
}

fn summarize(name: &str, times: &[f64]) -> BenchResult {
    let (mean, std) = mean_std(times);
    let median = crate::util::median(times);
    BenchResult {
        name: name.to_string(),
        samples: times.len(),
        mean_s: mean,
        median_s: median,
        std_s: std,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(r.samples, 5);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn bench_for_minimum_samples() {
        let r = bench_for("tiny", 0.0, 3, || 1 + 1);
        assert!(r.samples >= 3);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn json_escapes_name() {
        let r = bench("a\"b", 0, 1, || 0);
        assert!(r.to_json().contains("\\\""));
    }
}
