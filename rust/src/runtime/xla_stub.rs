//! Stub engine compiled when the `xla-pjrt` feature is off (the default
//! in the dependency-free build, including under the plain `xla`
//! feature): same API surface as the real `xla_engine::XlaLassoEngine`,
//! every entry point reporting that the PJRT backend is unavailable.
//! Callers that probe with `open(...)` (the e2e example, the benches)
//! degrade gracefully.

use crate::anyhow;
use crate::objective::LassoProblem;
use crate::solvers::common::{SolveOptions, SolveResult};
use crate::util::err::Result;
use std::path::Path;

pub struct XlaLassoEngine {
    _private: (),
}

impl XlaLassoEngine {
    pub fn open(_artifacts_dir: &Path, _profile: &str) -> Result<XlaLassoEngine> {
        Err(anyhow!(
            "XLA runtime not built: compile with `--features xla-pjrt` (needs the \
             external `xla` + `anyhow` crates; see rust/Cargo.toml)"
        ))
    }

    pub fn profile_shape(&self) -> (usize, usize, usize, usize) {
        unreachable!("stub engine cannot be constructed")
    }

    pub fn solve_lasso(
        &mut self,
        _prob: &LassoProblem,
        _x0: &[f64],
        _opts: &SolveOptions,
    ) -> Result<SolveResult> {
        unreachable!("stub engine cannot be constructed")
    }

    pub fn power_iter_rho(&mut self, _prob: &LassoProblem) -> Result<f64> {
        unreachable!("stub engine cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_reports_feature_gate() {
        let err = XlaLassoEngine::open(Path::new("artifacts"), "s").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
