//! The XLA-backed Shotgun engine for dense problems: synchronous block
//! rounds through the AOT-compiled L2 graph (`lasso_rounds`), whose flops
//! live in the L1 Pallas kernels. This is the TPU-shaped execution of
//! DESIGN.md §Hardware-Adaptation, run here on the PJRT CPU client.
//!
//! The rust coordinator still owns the randomness and the schedule: it
//! draws K x P coordinate blocks per device call (K fused rounds
//! amortize dispatch), feeds them as an i32 tensor, and carries the
//! residual/weight state across calls.

use super::Runtime;
use crate::metrics::{Stopwatch, Trace, TracePoint};
use crate::objective::LassoProblem;
use crate::sparsela::vecops;
use crate::solvers::common::{SolveOptions, SolveResult};
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

pub struct XlaLassoEngine {
    runtime: Runtime,
    profile: String,
}

impl XlaLassoEngine {
    pub fn open(artifacts_dir: &Path, profile: &str) -> Result<XlaLassoEngine> {
        let runtime = Runtime::open(artifacts_dir)?;
        if !runtime.manifest().profiles.contains_key(profile) {
            return Err(anyhow!("profile {profile} not in manifest"));
        }
        Ok(XlaLassoEngine {
            runtime,
            profile: profile.to_string(),
        })
    }

    pub fn profile_shape(&self) -> (usize, usize, usize, usize) {
        let p = &self.runtime.manifest().profiles[&self.profile];
        (p.n, p.d, p.p, p.k)
    }

    /// Solve a dense Lasso through the device graph. The problem must fit
    /// the profile (n <= N, d <= D); rows/columns are zero-padded, which
    /// is exact for both the residual and the coordinate updates.
    pub fn solve_lasso(
        &mut self,
        prob: &LassoProblem,
        x0: &[f64],
        opts: &SolveOptions,
    ) -> Result<SolveResult> {
        let (big_n, big_d, p, k) = self.profile_shape();
        let n = prob.n();
        let d = prob.d();
        if n > big_n || d > big_d {
            return Err(anyhow!(
                "problem ({n}x{d}) exceeds profile ({big_n}x{big_d})"
            ));
        }
        // stage A (zero-padded, row-major f32) once
        let dense = prob.a.to_dense();
        let mut a_pad = vec![0f32; big_n * big_d];
        for j in 0..d {
            let col = dense.col(j);
            for i in 0..n {
                a_pad[i * big_d + j] = col[i] as f32;
            }
        }
        // stage the design matrix + lambda on device ONCE (§Perf: the
        // dominant dispatch cost was re-uploading A every call)
        let a_buf = self.runtime.to_device_f32(&a_pad, &[big_n, big_d])?;
        let lam_buf = self
            .runtime
            .to_device_f32(&[prob.lam as f32], &[])?;
        // residual r = Ax - y (padded rows stay 0)
        let mut x = x0.to_vec();
        let r0 = prob.residual(&x);
        let mut r_f32: Vec<f32> = (0..big_n)
            .map(|i| if i < n { r0[i] as f32 } else { 0.0 })
            .collect();
        let mut x_f32: Vec<f32> = (0..big_d)
            .map(|j| if j < d { x[j] as f32 } else { 0.0 })
            .collect();

        let mut rng = Rng::new(opts.seed);
        let watch = Stopwatch::new();
        let mut trace = Trace::default();
        let f0 = prob.objective_from_residual(&r0, &x);
        trace.push(TracePoint {
            updates: 0,
            iters: 0,
            seconds: 0.0,
            objective: f0,
            nnz: vecops::nnz(&x, crate::ZERO_TOL),
            aux: 0.0,
        });

        let mut rounds = 0u64;
        let mut updates = 0u64;
        let mut converged = false;
        while rounds < opts.max_iters {
            // draw K rounds x P coordinates (multiset, over the real d)
            let idxs: Vec<i32> = (0..k * p).map(|_| rng.below(d) as i32).collect();
            let r_buf = self.runtime.to_device_f32(&r_f32, &[big_n])?;
            let x_buf = self.runtime.to_device_f32(&x_f32, &[big_d])?;
            let i_buf = self.runtime.to_device_i32(&idxs, &[k, p])?;
            let out = self.runtime.call_b(
                "lasso_rounds",
                &self.profile,
                &[&a_buf, &r_buf, &x_buf, &i_buf, &lam_buf],
            )?;
            let r_new: Vec<f32> = out[0].to_vec::<f32>().context("r out")?;
            let x_new: Vec<f32> = out[1].to_vec::<f32>().context("x out")?;
            // convergence check on the weight delta across the K rounds
            let mut max_dx: f32 = 0.0;
            for j in 0..d {
                max_dx = max_dx.max((x_new[j] - x_f32[j]).abs());
            }
            r_f32 = r_new;
            x_f32 = x_new;
            rounds += k as u64;
            updates += (k * p) as u64;
            let obj = {
                let rr: f64 = r_f32[..n].iter().map(|&v| (v as f64) * (v as f64)).sum();
                let l1: f64 = x_f32[..d].iter().map(|&v| (v as f64).abs()).sum();
                0.5 * rr + prob.lam * l1
            };
            trace.push(TracePoint {
                updates,
                iters: rounds,
                seconds: watch.seconds(),
                objective: obj,
                nnz: x_f32[..d].iter().filter(|v| v.abs() > 1e-8).count(),
                aux: 0.0,
            });
            if !obj.is_finite() || obj > 1e3 * f0.abs().max(1.0) {
                break; // diverged (P too large for this problem's rho)
            }
            if (max_dx as f64) < opts.tol.max(1e-6) {
                converged = true;
                break;
            }
            if opts.max_seconds > 0.0 && watch.seconds() > opts.max_seconds {
                break;
            }
        }
        for j in 0..d {
            x[j] = x_f32[j] as f64;
        }
        let objective = prob.objective(&x);
        Ok(SolveResult {
            solver: format!("shotgun-xla-p{p}"),
            x,
            objective,
            iters: rounds,
            updates,
            seconds: watch.seconds(),
            converged,
            trace,
        })
    }

    /// Estimate rho(A^T A) on device via the AOT `power_iter` graph.
    pub fn power_iter_rho(&mut self, prob: &LassoProblem) -> Result<f64> {
        let (big_n, big_d, _, _) = self.profile_shape();
        let n = prob.n();
        let d = prob.d();
        if n > big_n || d > big_d {
            return Err(anyhow!("problem exceeds profile"));
        }
        let dense = prob.a.to_dense();
        let mut a_pad = vec![0f32; big_n * big_d];
        for j in 0..d {
            let col = dense.col(j);
            for i in 0..n {
                a_pad[i * big_d + j] = col[i] as f32;
            }
        }
        // start vector: uniform over the real columns, 0 on padding
        let v: Vec<f32> = (0..big_d)
            .map(|j| if j < d { (1.0 / (d as f64).sqrt()) as f32 } else { 0.0 })
            .collect();
        // A staged on device once; v round-trips (it is big_d floats)
        let a_buf = self.runtime.to_device_f32(&a_pad, &[big_n, big_d])?;
        let mut v_host = v;
        let mut rho = 0f32;
        // a few chained device calls of `power_steps` iterations each
        for _ in 0..4 {
            let v_buf = self.runtime.to_device_f32(&v_host, &[big_d])?;
            let out = self
                .runtime
                .call_b("power_iter", &self.profile, &[&a_buf, &v_buf])?;
            v_host = out[0].to_vec::<f32>()?;
            rho = out[1].to_vec::<f32>()?[0];
        }
        Ok(rho as f64)
    }
}

// NOTE: integration tests that exercise the PJRT path live in
// rust/tests/xla_integration.rs (they need `make artifacts` to have run).
