//! The PJRT client plumbing (feature `xla` only): compiled-artifact
//! cache over one CPU client plus the host<->device literal helpers.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

use super::Manifest;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact cache over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open `artifacts/` (reads `manifest.json`, creates the CPU client).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            compiled: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the executable for `entry`/`profile`.
    pub fn get(&mut self, entry: &str, profile: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{entry}.{profile}");
        if !self.compiled.contains_key(&key) {
            let spec = self
                .manifest
                .find(entry, profile)
                .with_context(|| format!("artifact {key} not in manifest"))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {key}"))?;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(&self.compiled[&key])
    }

    /// Execute an entry with literal inputs; returns the output tuple
    /// elements (AOT lowers with `return_tuple=True`).
    pub fn call(
        &mut self,
        entry: &str,
        profile: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.get(entry, profile)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {entry}.{profile}"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Stage host data as a device buffer (upload once, reuse across
    /// calls — the §Perf fix for re-uploading the design matrix on every
    /// dispatch).
    pub fn to_device_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn to_device_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute with device buffers (no host->device copies of staged
    /// arguments); returns the output tuple elements as literals.
    pub fn call_b(
        &mut self,
        entry: &str,
        profile: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.get(entry, profile)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("execute_b {entry}.{profile}"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// f32 vector -> rank-1 literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 matrix (row-major) -> rank-2 literal.
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// i32 vector -> rank-1 literal.
pub fn lit_i32(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// 2-D i32 (row-major) literal.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_fails_cleanly() {
        let err = match Runtime::open(Path::new("/nonexistent/artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("open should fail"),
        };
        assert!(err.to_string().contains("manifest"), "{err}");
    }

    #[test]
    fn open_bad_manifest_fails_cleanly() {
        let dir = std::env::temp_dir().join("shotgun_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Runtime::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn unknown_entry_rejected() {
        // only meaningful when artifacts exist
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.get("no_such_entry", "s").is_err());
        assert!(rt.get("lasso_round", "no_such_profile").is_err());
    }

    #[test]
    fn missing_artifact_file_reported() {
        let dir = std::env::temp_dir().join("shotgun_missing_artifact");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"profiles": {"s": {"n": 4, "d": 4, "p": 1, "k": 1, "power_steps": 1}},
                "artifacts": [{"entry": "lasso_round", "profile": "s",
                               "file": "does_not_exist.hlo.txt", "args": []}]}"#,
        )
        .unwrap();
        let mut rt = Runtime::open(&dir).unwrap();
        let err = match rt.get("lasso_round", "s") {
            Err(e) => e,
            Ok(_) => panic!("get should fail"),
        };
        assert!(err.to_string().contains("does_not_exist"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }
}
