//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from the L2 JAX graphs + L1 Pallas
//! kernels) and executes them from the rust hot path. Python is never on
//! the request path.
//!
//! The manifest layer ([`artifacts`]) is always available and
//! dependency-free. The PJRT client itself ([`pjrt`](self)) and the real
//! [`xla_engine`] need the external `xla` crate, which the vendored
//! build environment does not carry — they are gated behind the
//! `xla-pjrt` cargo feature, with [`xla_stub`] providing an
//! API-compatible engine that reports itself unavailable otherwise. The
//! plain `xla` feature compiles the stub surface plus
//! `tests/xla_integration.rs` (runtime-skipped without `artifacts/`),
//! which is what the CI `cargo check --features xla --all-targets` step
//! keeps honest.

pub mod artifacts;

pub use artifacts::{ArtifactSpec, Manifest};

#[cfg(feature = "xla-pjrt")]
mod pjrt;
#[cfg(feature = "xla-pjrt")]
pub use pjrt::{lit_f32, lit_f32_2d, lit_i32, lit_i32_2d, lit_scalar, Runtime};

#[cfg(feature = "xla-pjrt")]
pub mod xla_engine;
#[cfg(feature = "xla-pjrt")]
pub use xla_engine::XlaLassoEngine;

#[cfg(not(feature = "xla-pjrt"))]
pub mod xla_stub;
#[cfg(not(feature = "xla-pjrt"))]
pub use xla_stub::XlaLassoEngine;
