//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from the L2 JAX graphs + L1 Pallas
//! kernels) and executes them from the rust hot path. Python is never on
//! the request path.
//!
//! The manifest layer ([`artifacts`]) is always available and
//! dependency-free. The PJRT client itself ([`pjrt`](self)) and the real
//! [`xla_engine`] need the external `xla` crate, which the vendored
//! build environment does not carry — they are gated behind the `xla`
//! cargo feature, with [`xla_stub`] providing an API-compatible engine
//! that reports itself unavailable when the feature is off.

pub mod artifacts;

pub use artifacts::{ArtifactSpec, Manifest};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{lit_f32, lit_f32_2d, lit_i32, lit_i32_2d, lit_scalar, Runtime};

#[cfg(feature = "xla")]
pub mod xla_engine;
#[cfg(feature = "xla")]
pub use xla_engine::XlaLassoEngine;

#[cfg(not(feature = "xla"))]
pub mod xla_stub;
#[cfg(not(feature = "xla"))]
pub use xla_stub::XlaLassoEngine;
