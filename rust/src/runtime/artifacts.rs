//! Manifest-driven artifact discovery: `aot.py` writes
//! `artifacts/manifest.json` describing every lowered entrypoint (file,
//! shapes, dtypes, profile); the runtime never hardcodes shapes.

use crate::anyhow;
use crate::util::json::Json;
use crate::util::err::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One argument's shape/dtype.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgDesc {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub entry: String,
    pub profile: String,
    pub file: String,
    pub args: Vec<ArgDesc>,
}

/// A shape profile (n, d, p, k, power_steps).
#[derive(Clone, Debug, Default)]
pub struct Profile {
    pub n: usize,
    pub d: usize,
    pub p: usize,
    pub k: usize,
    pub power_steps: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub profiles: BTreeMap<String, Profile>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let mut m = Manifest::default();
        let profs = j
            .get("profiles")
            .and_then(|p| p.as_obj())
            .ok_or_else(|| anyhow!("manifest missing profiles"))?;
        for (tag, p) in profs {
            let g = |k: &str| p.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            m.profiles.insert(
                tag.clone(),
                Profile {
                    n: g("n"),
                    d: g("d"),
                    p: g("p"),
                    k: g("k"),
                    power_steps: g("power_steps"),
                },
            );
        }
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for a in arts {
            let gets = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let mut args = Vec::new();
            if let Some(list) = a.get("args").and_then(|v| v.as_arr()) {
                for arg in list {
                    let shape = arg
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|s| s.iter().filter_map(|v| v.as_usize()).collect())
                        .unwrap_or_default();
                    let dtype = arg
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    args.push(ArgDesc { shape, dtype });
                }
            }
            m.artifacts.push(ArtifactSpec {
                entry: gets("entry")?,
                profile: gets("profile")?,
                file: gets("file")?,
                args,
            });
        }
        Ok(m)
    }

    pub fn find(&self, entry: &str, profile: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.entry == entry && a.profile == profile)
    }

    /// Smallest profile whose (n, d) dominate the given problem size.
    pub fn profile_for(&self, n: usize, d: usize) -> Option<(&str, &Profile)> {
        self.profiles
            .iter()
            .filter(|(_, p)| p.n >= n && p.d >= d)
            .min_by_key(|(_, p)| p.n * p.d)
            .map(|(tag, p)| (tag.as_str(), p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "profiles": {"s": {"n": 256, "d": 512, "p": 8, "k": 8, "power_steps": 16},
                       "m": {"n": 512, "d": 2048, "p": 16, "k": 16, "power_steps": 32}},
          "artifacts": [
            {"entry": "lasso_round", "profile": "s", "file": "lasso_round.s.hlo.txt",
             "args": [{"shape": [256, 512], "dtype": "float32"},
                      {"shape": [256], "dtype": "float32"}]},
            {"entry": "lasso_round", "profile": "m", "file": "lasso_round.m.hlo.txt",
             "args": []}
          ]
        }"#
    }

    #[test]
    fn parses_profiles_and_artifacts() {
        let m = Manifest::parse(sample()).unwrap();
        assert_eq!(m.profiles["s"].n, 256);
        assert_eq!(m.profiles["m"].d, 2048);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("lasso_round", "s").unwrap();
        assert_eq!(a.file, "lasso_round.s.hlo.txt");
        assert_eq!(a.args[0].shape, vec![256, 512]);
        assert_eq!(a.args[1].shape, vec![256]);
    }

    #[test]
    fn find_misses_cleanly() {
        let m = Manifest::parse(sample()).unwrap();
        assert!(m.find("nope", "s").is_none());
        assert!(m.find("lasso_round", "xl").is_none());
    }

    #[test]
    fn profile_selection_smallest_dominating() {
        let m = Manifest::parse(sample()).unwrap();
        assert_eq!(m.profile_for(100, 400).unwrap().0, "s");
        assert_eq!(m.profile_for(300, 1000).unwrap().0, "m");
        assert!(m.profile_for(10_000, 10).is_none());
    }

    #[test]
    fn real_manifest_if_built() {
        // integration hook: when `make artifacts` has run, the real
        // manifest must parse and contain every entrypoint x profile
        let path = std::path::Path::new("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(path).unwrap();
        for entry in [
            "lasso_round",
            "lasso_rounds",
            "lasso_objective",
            "logistic_round",
            "logistic_objective",
            "power_iter",
        ] {
            for profile in m.profiles.keys() {
                assert!(
                    m.find(entry, profile).is_some(),
                    "missing {entry}.{profile}"
                );
            }
        }
    }
}
