//! `repro` — the Shotgun reproduction CLI (leader entrypoint).
//!
//! Subcommands:
//!   solve          solve one problem with any registered solver
//!   solvers        list the solver registry + capabilities
//!   serve          fit + publish a model, replay a request stream
//!                  against the batching server, report throughput and
//!                  latency percentiles into BENCH_serving.json
//!   sim            run the deterministic simserve scenario suite
//!                  (virtual time, real serving components) and report
//!                  outcome stats into BENCH_simserve.json
//!   estimate-pstar power-iteration rho + P* for a dataset
//!   bench <exp>    regenerate a paper table/figure
//!                  (fig2|fig3|fig4|fig5|bounds|headline|ablations|all)
//!   xla-demo       run the dense Shotgun engine through the PJRT runtime
//!   gen-data       write a synthetic dataset in LIBSVM format
//!   info           environment + artifact status
//!
//! Solving goes through the `shotgun::api::Fit` front door: solver
//! lookup by registry name (no hand-rolled match arms), typed errors
//! instead of panics, and `--solver auto` (the default) picks P from
//! Theorem 3.2. Run `repro help` for flags.

use shotgun::api::{Engine, Fit, PathSpec, ShotgunError, SolverParams, SolverRegistry};
use shotgun::bench::{self, BenchConfig};
use shotgun::coordinator::{AccumulatorMode, PStar, SchedulePolicy};
use shotgun::data::{libsvm, synth, Dataset};
use shotgun::objective::{HuberProblem, LassoProblem, LogisticProblem, Loss, SqHingeProblem};
use shotgun::runtime::XlaLassoEngine;
use shotgun::solvers::common::SolveOptions;
use shotgun::solvers::sgd::Sgd;
use shotgun::util::cli::Args;
use std::path::Path;

const HELP: &str = r#"repro — Shotgun (parallel coordinate descent for L1) reproduction

USAGE:
  repro solve --data <spec> [--solver auto] [--p 8] [--lam 0.5]
              [--loss squared|logistic|sqhinge|huber] [--tol 1e-7]
              [--max-iters N] [--budget secs] [--seed 42] [--eta R]
              [--sparsity K] [--huber-delta D] [--adapt-p K]
              [--schedule uniform|clustered[:K]]
              [--accumulator atomic|sharded[:T]]
              [--path-to LAM [--path-stages 6]]
              [--trace-out f.csv]
  repro solvers
  repro serve --data <spec> [--lam 0.1] [--loss squared|logistic|sqhinge|huber]
              [--solver auto] [--requests 10000] [--max-nnz 8]
              [--proba-frac 0.0] [--file reqs.jsonl]
              [--gen-requests out.jsonl] [--max-batch 64]
              [--max-wait-us 2000] [--clients 4] [--fit-workers 2]
              [--models N] [--shards S] [--max-in-flight M]
              [--fairness firstseen|drr[:Q]]
              [--bench-out BENCH_serving.json] [--store-out dir]
              [--compare-unbatched]
  repro sim [--smoke] [--seed 42] [--scenario <name>]
            [--bench-out BENCH_simserve.json]
  repro estimate-pstar --data <spec> [--seed 42]
  repro bench <fig2|fig3|fig4|fig5|bounds|headline|ablations|beyond|kernels|all>
              [--scale 0.25] [--out results] [--seed 42] [--budget 60]
  repro xla-demo [--artifacts artifacts] [--profile s] [--n 128] [--d 128]
  repro gen-data --data <spec> --out <file.svm>
  repro info

DATA SPECS (--data):
  file:<path.svm>                 LIBSVM file
  sparco:<n>x<d>:<density>        e.g. sparco:512x1024:0.05
  singlepix-pm1:<n>x<d>           Mug32-like (low rho)
  singlepix-binary:<n>x<d>        Ball64-like (rho ~ d/2)
  imaging:<n>x<d>:<density>       sparse compressed imaging
  text:<n>x<d>                    large sparse text-like
  zeta:<n>x<d>                    dense logistic, n >> d
  rcv1:<n>x<d>:<density>          sparse logistic, d > n
  correlated:<n>x<d>:<c>          correlation dial c in [0,1]

SOLVERS (--solver): "auto" (Theorem 3.2 picks P and the engine),
  "portfolio" (race {exact, atomic, sharded, cdn} x {P*, P*/2, hw} to
  tolerance; first to converge cancels the rest), or any registry name —
  run `repro solvers` for the roster + capabilities.
  (legacy: `--solver shotgun --engine threaded` maps to shotgun-threaded)

ONLINE P ADAPTATION (threaded engine):
  --adapt-p K   every K monitor wakes (atomic) / K merge rounds
                (sharded), re-estimate rho from the observed update
                directions (Rayleigh quotient) and resize the live
                worker set to ceil(d/rho_hat), bounded by the hardware
                pool (0 = off, the default)

SCHEDULING (schedule-aware solvers only — the "sched" set in
  `repro solvers`):
  --schedule clustered[:K]   stratify each parallel round across K
                             correlation clusters (K omitted or 0 = auto)
  --accumulator sharded[:T]  threaded engine: bulk-synchronous per-worker
                             shards (T threads; 0 = P) merged at round
                             boundaries instead of atomic CAS — and
                             bit-identical to the exact engine

SERVE REQUEST FORMAT (--file, one JSON object per line; blank lines and
  `#` comments skipped):
    {"features":[[3,0.5],[17,-1.25]]}
    {"features":[[0,2.0]],"proba":true}
  "features" is the sparse request row as [index, value] pairs (indices
  need not be sorted; duplicates sum); "proba" additionally asks for
  P(y=+1) and requires a logistic model. Without --file, `serve`
  generates a seeded stream (--requests/--max-nnz/--proba-frac);
  --gen-requests writes that stream as JSONL and exits.
  --models N (default 1): also replay the stream routed round-robin
  across N copies of the fitted model through ONE router collector,
  with a hot-swap loop hammering the first name; emits
  derived.multi_model_routing_overhead and derived.shard_swap_stall_us.
  --shards S (default 8) sizes the ModelStore's consistent-hash shard
  map; --max-in-flight M (default unbounded) turns on admission
  control (excess requests shed with a typed Overloaded error).

SIM (repro sim): the deterministic serving simulator — REAL
  BatchServer/FitQueue threads on a virtual clock, so every outcome
  stat (batches, latency percentiles, fault counters) is a pure
  function of the scenario + seed. --smoke (or SHOTGUN_BENCH_SMOKE=1)
  shrinks horizons for CI; --scenario <name> runs one scenario and
  skips the bench JSON (its derived metrics need the full suite).
  Scenarios: baseline-batch8, baseline-batch64, diurnal, bursty,
  zipf-hot-model, worker-panic-recovery, hot-swap-under-load,
  queue-saturation, client-stall, multi-model-routing,
  shard-swap-under-load, priority-inversion, overload-shedding.
"#;

fn parse_dims(s: &str) -> (usize, usize) {
    let (n, d) = s.split_once('x').expect("expected <n>x<d>");
    (n.parse().expect("bad n"), d.parse().expect("bad d"))
}

fn parse_loss(args: &Args) -> Loss {
    let s = args.get_or("loss", "squared");
    Loss::parse(&s)
        .unwrap_or_else(|| panic!("unknown --loss {s:?} (squared|logistic|sqhinge|huber)"))
}

/// `--schedule uniform | clustered[:K]` (omitted K = auto-sized).
fn parse_schedule(s: &str) -> SchedulePolicy {
    match (s, s.split_once(':')) {
        ("uniform", _) => SchedulePolicy::Uniform,
        ("clustered", _) => SchedulePolicy::Clustered { clusters: 0 },
        (_, Some(("clustered", k))) => SchedulePolicy::Clustered {
            clusters: k.parse().expect("bad --schedule cluster count"),
        },
        _ => panic!("unknown --schedule {s:?} (uniform|clustered[:K])"),
    }
}

/// `--accumulator atomic | sharded[:T]` (omitted T = P threads).
fn parse_accumulator(s: &str) -> AccumulatorMode {
    match (s, s.split_once(':')) {
        ("atomic", _) => AccumulatorMode::Atomic,
        ("sharded", _) => AccumulatorMode::Sharded { threads: 0 },
        (_, Some(("sharded", t))) => AccumulatorMode::Sharded {
            threads: t.parse().expect("bad --accumulator thread count"),
        },
        _ => panic!("unknown --accumulator {s:?} (atomic|sharded[:T])"),
    }
}

fn load_data(spec: &str, seed: u64) -> Dataset {
    if let Some(path) = spec.strip_prefix("file:") {
        return libsvm::load(Path::new(path), true).expect("load LIBSVM file");
    }
    let mut parts = spec.split(':');
    let kind = parts.next().unwrap_or_default();
    let dims = parts.next().unwrap_or("256x512");
    let (n, d) = parse_dims(dims);
    let extra: f64 = parts
        .next()
        .map(|v| v.parse().expect("bad param"))
        .unwrap_or(0.05);
    match kind {
        "sparco" => synth::sparco_like(n, d, extra, seed),
        "singlepix-pm1" => synth::singlepix_pm1(n, d, seed),
        "singlepix-binary" => synth::singlepix_binary(n, d, seed),
        "imaging" => synth::sparse_imaging(n, d, extra, seed),
        "text" => synth::large_sparse_text(n, d, seed),
        "zeta" => synth::zeta_like(n, d, seed),
        "rcv1" => synth::rcv1_like(n, d, extra.max(0.01), seed),
        "correlated" => synth::correlated(n, d, extra, seed),
        other => panic!("unknown data spec {other:?} (see `repro help`)"),
    }
}

fn cmd_solve(args: &Args) -> Result<(), ShotgunError> {
    let seed = args.usize_or("seed", 42) as u64;
    let ds = load_data(&args.get_or("data", "sparco:256x512:0.05"), seed);
    let lam = args.f64_or("lam", 0.5);
    let p = args.usize_or("p", 8);
    let solver_name = args.get_or("solver", "auto");
    let loss = parse_loss(args);
    let registry = SolverRegistry::global();

    // the paper's SGD protocol: sweep a constant rate when the chosen
    // solver is rate-swept and the user gave no --eta
    let needs_sweep = registry
        .capabilities(&solver_name)
        .is_some_and(|c| c.rate_swept)
        && args.get("eta").is_none();
    let eta = if needs_sweep {
        let sweep_opts = SolveOptions {
            max_iters: 3,
            seed,
            ..Default::default()
        };
        let x0 = vec![0.0; ds.d()];
        let eta = match loss {
            Loss::Logistic => {
                let prob = LogisticProblem::new(&ds.design, &ds.targets, lam);
                Sgd::sweep(&prob, &x0, &sweep_opts, 1e-4, 1.0, 7).0
            }
            Loss::Squared => {
                let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
                Sgd::sweep(&prob, &x0, &sweep_opts, 1e-4, 1.0, 7).0
            }
            Loss::SqHinge => {
                let prob = SqHingeProblem::new(&ds.design, &ds.targets, lam);
                Sgd::sweep(&prob, &x0, &sweep_opts, 1e-4, 1.0, 7).0
            }
            Loss::Huber => {
                let prob = HuberProblem::new(&ds.design, &ds.targets, lam);
                Sgd::sweep(&prob, &x0, &sweep_opts, 1e-4, 1.0, 7).0
            }
        };
        println!("{solver_name}: swept rate eta = {eta}");
        eta
    } else {
        args.f64_or("eta", 0.1)
    };

    println!(
        "dataset {} (n={}, d={}, density={:.3}), lam={lam}, solver={solver_name}",
        ds.name,
        ds.n(),
        ds.d(),
        ds.design.density()
    );
    let mut fit = Fit::new(&ds.design, &ds.targets)
        .loss(loss)
        .lambda(lam)
        .params(SolverParams {
            p,
            eta,
            sparsity: args.get("sparsity").and_then(|s| s.parse().ok()),
            huber_delta: args
                .get("huber-delta")
                .map(|s| s.parse().expect("bad --huber-delta")),
            ..Default::default()
        })
        .options(|o| {
            o.max_iters = args.usize_or("max-iters", 1_000_000) as u64;
            o.max_seconds = args.f64_or("budget", 0.0);
            o.tol = args.f64_or("tol", 1e-7);
            o.record_every = args.usize_or("record-every", 256) as u64;
            o.seed = seed;
            o.adapt_p_every = args.usize_or("adapt-p", 0) as u64;
            if let Some(s) = args.get("schedule") {
                o.schedule = parse_schedule(&s);
            }
            if let Some(s) = args.get("accumulator") {
                o.accumulator = parse_accumulator(&s);
            }
        });
    if let Some(target) = args.get("path-to") {
        let target: f64 = target.parse().map_err(|_| ShotgunError::InvalidPath {
            reason: format!("--path-to {target:?} is not a number"),
        })?;
        fit = fit.path(PathSpec {
            lam_target: target,
            stages: args.usize_or("path-stages", 6),
            strong_rules: true,
        });
    }
    // legacy `--engine threaded` (pre-registry CLI) still selects the
    // threaded engine rather than being silently ignored
    let engine_flag = args.get("engine");
    fit = match (solver_name.as_str(), engine_flag) {
        ("auto", _) => fit.engine(Engine::Auto),
        // Engine::Portfolio (not the bare registry entry) so the roster
        // scales off the memoized P* estimate instead of --p
        ("portfolio", _) | (_, Some("portfolio")) => fit.engine(Engine::Portfolio),
        ("shotgun", Some("threaded")) => fit.solver("shotgun-threaded"),
        (name, _) => fit.solver(name),
    };
    let report = fit.run()?;
    if let Some(auto) = &report.auto {
        println!(
            "auto engine: rho = {:.4} -> P* = {} (Theorem 3.2), running {} at P = {}",
            auto.rho,
            auto.p_star,
            if auto.threaded { "threaded" } else { "exact" },
            auto.p
        );
    }
    if let Some(pf) = &report.portfolio {
        println!(
            "portfolio race: {} won over {} losers",
            pf.winner,
            pf.losers.len()
        );
        for l in &pf.losers {
            println!(
                "  {:<14} cancelled at {} iters (F = {:.6}, {:.3}s{})",
                l.label,
                l.iters_at_cancel,
                l.objective,
                l.seconds,
                if l.converged { ", converged" } else { "" }
            );
        }
    }
    let res = &report.diagnostics;
    println!(
        "{}: F = {:.8}  nnz = {}  iters = {}  updates = {}  time = {:.3}s  converged = {}",
        res.solver,
        res.objective,
        report.model.nnz(),
        res.iters,
        res.updates,
        res.seconds,
        res.converged
    );
    if let Some(out) = args.get("trace-out") {
        std::fs::write(out, res.trace.to_csv()).expect("write trace");
        println!("trace written to {out}");
    }
    if let Some(out) = args.get("model-out") {
        std::fs::write(out, report.model.to_json()).expect("write model");
        println!("model written to {out}");
    }
    Ok(())
}

/// `repro serve`: the end-to-end serving story. Fit through the
/// [`FitQueue`] (publishing into a [`ModelStore`]), then replay a
/// request stream (seeded synthetic or `--file` JSONL) against the
/// batching server and report throughput + latency percentiles into
/// `--bench-out` (default `BENCH_serving.json`).
fn cmd_serve(args: &Args) -> Result<(), ShotgunError> {
    use shotgun::api::serve::{
        replay, replay_multi, BatchConfig, FitJob, FitQueue, FlushFairness, JobState, ModelStore,
        ReplayConfig,
    };
    use shotgun::testkit::requests::{self, StreamSpec};
    use std::sync::Arc;
    use std::time::Duration;

    let seed = args.usize_or("seed", 42) as u64;
    let ds = load_data(&args.get_or("data", "imaging:512x1024:0.02"), seed);
    let loss = parse_loss(args);
    let lam = args.f64_or("lam", 0.1);
    let solver_name = args.get_or("solver", "auto");
    let dataset_tag = format!("{} (n={}, d={})", ds.name, ds.n(), ds.d());
    let d = ds.d();

    // --- request stream: --file JSONL, or a seeded synthetic stream ---
    let spec = StreamSpec {
        d,
        count: args.usize_or("requests", 10_000),
        max_nnz: args.usize_or("max-nnz", 8),
        proba_fraction: if loss == Loss::Logistic {
            args.f64_or("proba-frac", 0.0)
        } else {
            0.0
        },
    };
    let io_err = |path: &str, what: &str, e: std::io::Error| ShotgunError::Io {
        path: path.to_string(),
        reason: format!("{what}: {e}"),
    };
    let request_stream = match args.get("file") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| io_err(path, "read requests", e))?;
            requests::from_jsonl(&text)?
        }
        None => requests::stream(&spec, seed ^ 0x5e21),
    };
    if let Some(out) = args.get("gen-requests") {
        std::fs::write(out, requests::to_jsonl(&request_stream))
            .map_err(|e| io_err(out, "write requests", e))?;
        println!("wrote {} requests to {out}", request_stream.len());
        return Ok(());
    }

    // --- fit side: queue the training job, publish into the store ---
    let store = Arc::new(ModelStore::with_shards(args.usize_or("shards", 8)));
    let queue = FitQueue::with_store(
        args.usize_or("fit-workers", 2),
        args.usize_or("fit-capacity", 16),
        Arc::clone(&store),
    )?;
    let design = Arc::new(ds.design);
    let targets = Arc::new(ds.targets);
    let mut job = FitJob::new(design, targets, loss, lam)
        .options(|o| {
            o.max_iters = args.usize_or("max-iters", 1_000_000) as u64;
            o.max_seconds = args.f64_or("budget", 0.0);
            o.tol = args.f64_or("tol", 1e-7);
            o.seed = seed;
        })
        .publish_as("default");
    job.params.p = args.usize_or("p", 8);
    job.params.huber_delta = args
        .get("huber-delta")
        .map(|s| s.parse().expect("bad --huber-delta"));
    if solver_name != "auto" {
        job = job.solver_name(solver_name.clone());
    }
    let id = queue.submit(job)?;
    let report = match queue.wait(id).expect("submitted job is known") {
        JobState::Done(report) => report,
        JobState::Failed(e) => return Err(e),
        other => unreachable!("wait() returns terminal states, got {other:?}"),
    };
    let record = store.resolve("default")?;
    println!(
        "fitted {dataset_tag}: {} -> F = {:.6}, nnz = {}, published as \"default\" v{}",
        report.diagnostics.solver,
        report.objective(),
        report.model.nnz(),
        record.version
    );

    // --- serve side: replay the stream through the batching server ---
    // --fairness firstseen (default) | drr[:quantum] — the flush-time
    // row selection policy when the backlog exceeds max_batch
    let fairness_arg = args.get_or("fairness", "firstseen");
    let fairness = match fairness_arg.as_str() {
        "firstseen" => FlushFairness::FirstSeen,
        "drr" => FlushFairness::DeficitRr { quantum: 4 },
        s => match s.strip_prefix("drr:").and_then(|q| q.parse().ok()) {
            Some(quantum) if quantum > 0 => FlushFairness::DeficitRr { quantum },
            _ => panic!("unknown --fairness {s:?} (firstseen | drr[:quantum])"),
        },
    };
    let cfg = ReplayConfig {
        batch: BatchConfig {
            max_batch: args.usize_or("max-batch", 64),
            max_wait: Duration::from_micros(args.usize_or("max-wait-us", 2_000) as u64),
            max_in_flight: args.usize_or("max-in-flight", usize::MAX),
            fairness,
            ..BatchConfig::default()
        },
        clients: args.usize_or("clients", 4),
    };
    println!(
        "replaying {} requests (max_batch {}, max_wait {}us, {} clients, fairness {:?})...",
        request_stream.len(),
        cfg.batch.max_batch,
        cfg.batch.max_wait.as_micros(),
        cfg.clients,
        cfg.batch.fairness
    );
    let stats = replay(Arc::clone(&store), "default", &request_stream, &cfg)?;
    println!("{}", stats.report_line());

    // --compare-unbatched: replay the same stream at max_batch = 1 so
    // BENCH_serving.json carries the batching-on/off speedup as a
    // derived field (the CI bench-smoke gate checks it is a number)
    let unbatched = if args.bool("compare-unbatched") {
        let cfg1 = shotgun::api::serve::ReplayConfig {
            batch: shotgun::api::serve::BatchConfig {
                max_batch: 1,
                ..cfg.batch
            },
            clients: cfg.clients,
        };
        let base = replay(Arc::clone(&store), "default", &request_stream, &cfg1)?;
        println!("unbatched {}", base.report_line());
        println!(
            "batching speedup: {:.2}x throughput",
            stats.throughput_rps / base.throughput_rps.max(1e-12)
        );
        Some(base)
    } else {
        None
    };

    // --models N: the same stream routed round-robin across N copies of
    // the fitted model through ONE router collector, with a hot-swap
    // loop republishing the first name the whole time — the routing
    // overhead and worst swap stall become derived bench fields
    let models = args.usize_or("models", 1);
    let multi = if models > 1 {
        let names: Vec<String> = (0..models).map(|i| format!("m{i}")).collect();
        for name in &names {
            store.publish(name, (*record.model).clone());
        }
        let m = replay_multi(
            Arc::clone(&store),
            &names,
            &request_stream,
            &cfg,
            Some(record.model.as_ref()),
        )?;
        println!(
            "multi-tenant ({} models, {} shards): {:.0} req/s | worst swap stall {:.1}us | {} shed",
            m.models, m.shards, m.throughput_rps, m.swap_stall_us, m.shed
        );
        Some(m)
    } else {
        None
    };

    let bench_out = args.get_or("bench-out", "BENCH_serving.json");
    std::fs::write(
        &bench_out,
        stats.to_bench_json(
            &dataset_tag,
            &report.diagnostics.solver,
            unbatched.as_ref(),
            multi.as_ref(),
        ),
    )
    .map_err(|e| io_err(&bench_out, "write bench json", e))?;
    println!("serving benchmark written to {bench_out}");

    if let Some(dir) = args.get("store-out") {
        store.save_dir(std::path::Path::new(&dir))?;
        println!("model store persisted to {dir}/");
    }
    Ok(())
}

/// `repro sim`: run the simserve scenario suite to quiescence on
/// virtual time and write `BENCH_simserve.json`. With `--scenario` only
/// that scenario runs and no bench JSON is written (the derived metrics
/// read specific named scenarios from the full suite).
fn cmd_sim(args: &Args) -> Result<(), ShotgunError> {
    use shotgun::simserve::report::{report_line, run_suite, suite};

    let seed = args.usize_or("seed", 42) as u64;
    let smoke = args.bool("smoke")
        || std::env::var("SHOTGUN_BENCH_SMOKE").ok().as_deref() == Some("1");
    let filter = args.get("scenario");
    if let Some(name) = filter {
        let names: Vec<&str> = suite(smoke, seed).iter().map(|s| s.name).collect();
        if !names.contains(&name) {
            return Err(ShotgunError::BadRequest {
                index: 0,
                reason: format!(
                    "unknown scenario {name:?} (valid: {})",
                    names.join(", ")
                ),
            });
        }
    }
    println!(
        "simserve suite ({}, seed {seed}): real serving components, virtual time",
        if smoke { "smoke" } else { "full" }
    );
    let report = run_suite(smoke, seed, filter)?;
    for o in &report.outcomes {
        println!("{}", report_line(o));
    }
    let requests: u64 = report.outcomes.iter().map(|o| o.requests).sum();
    println!(
        "{} scenarios, {} requests, {} responses bit-checked against sequential predict",
        report.outcomes.len(),
        requests,
        report
            .outcomes
            .iter()
            .map(|o| o.bit_identity_checked)
            .sum::<u64>()
    );
    if filter.is_none() {
        let out = args.get_or("bench-out", "BENCH_simserve.json");
        std::fs::write(&out, report.to_bench_json()).map_err(|e| ShotgunError::Io {
            path: out.clone(),
            reason: format!("write bench json: {e}"),
        })?;
        println!("simulation benchmark written to {out}");
    } else {
        println!("(--scenario filter active; BENCH_simserve.json not written)");
    }
    Ok(())
}

fn cmd_solvers() {
    let registry = SolverRegistry::global();
    println!(
        "{:<18} {:<32} {:>8} {:>13} {:>6} {:<8} {}",
        "solver", "losses", "parallel", "deterministic", "exact", "unit", "sets"
    );
    for e in registry.entries() {
        let mut sets = Vec::new();
        if e.caps.fig3_lasso {
            sets.push("fig3");
        }
        if e.caps.fig4_logreg {
            sets.push("fig4");
        }
        if e.caps.rate_swept {
            sets.push("rate-swept");
        }
        if e.caps.schedule_aware {
            sets.push("sched");
        }
        println!(
            "{:<18} {:<32} {:>8} {:>13} {:>6} {:<8} {}",
            e.name,
            e.caps.losses.names(),
            e.caps.parallel,
            e.caps.deterministic,
            e.caps.exact_optimum,
            format!("{:?}", e.caps.iter_unit).to_lowercase(),
            sets.join(",")
        );
    }
}

fn cmd_estimate_pstar(args: &Args) {
    let seed = args.usize_or("seed", 42) as u64;
    let ds = load_data(&args.get_or("data", "sparco:256x512:0.05"), seed);
    let est = PStar::estimate(&ds.design, args.usize_or("max-iters", 500), 1e-6, seed);
    println!(
        "dataset {} (n={}, d={}): rho(A^T A) = {:.4}, P* = ceil(d/rho) = {} ({} power iterations, {:.4}s)",
        ds.name,
        ds.n(),
        ds.d(),
        est.rho,
        est.p_star,
        est.iters,
        est.seconds
    );
}

fn cmd_bench(args: &Args) {
    let cfg = BenchConfig {
        scale: args.f64_or("scale", 0.25),
        seed: args.usize_or("seed", 42) as u64,
        out_dir: args.get_or("out", "results"),
        rel_tol: args.f64_or("rel-tol", 0.005),
        max_seconds: args.f64_or("budget", 60.0),
    };
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    match which {
        "fig2" => bench::fig2::run(&cfg),
        "fig3" => bench::fig3::run(&cfg),
        "fig4" => bench::fig4::run(&cfg),
        "fig5" => bench::fig5::run(&cfg),
        "bounds" => bench::bounds::run(&cfg),
        "headline" => bench::headline::run(&cfg),
        "ablations" => bench::ablations::run(&cfg),
        "beyond" => bench::beyond::run(&cfg),
        "kernels" => bench::kernels::run(&cfg),
        "all" => bench::run_all(&cfg),
        other => panic!("unknown experiment {other:?}"),
    }
    println!("\nreports written to {}/", cfg.out_dir);
}

fn cmd_xla_demo(args: &Args) {
    let dir = args.get_or("artifacts", "artifacts");
    let profile = args.get_or("profile", "s");
    let n = args.usize_or("n", 128);
    let d = args.usize_or("d", 128);
    let seed = args.usize_or("seed", 42) as u64;
    let mut engine = XlaLassoEngine::open(Path::new(&dir), &profile).expect("open runtime");
    let (big_n, big_d, p, k) = engine.profile_shape();
    println!("PJRT runtime up: profile {profile} (N={big_n}, D={big_d}, P={p}, K={k})");
    let ds = synth::singlepix_pm1(n, d, seed);
    let prob = LassoProblem::new(&ds.design, &ds.targets, args.f64_or("lam", 0.3));
    let rho = engine.power_iter_rho(&prob).expect("device power iteration");
    println!(
        "device power iteration: rho = {rho:.4}, P* = {}",
        shotgun::sparsela::power::p_star(d, rho)
    );
    let opts = SolveOptions {
        max_iters: args.usize_or("max-iters", 4_000) as u64,
        tol: 1e-5,
        seed,
        ..Default::default()
    };
    let res = engine
        .solve_lasso(&prob, &vec![0.0; d], &opts)
        .expect("device solve");
    println!(
        "{}: F = {:.6}  nnz = {}  device rounds = {}  time = {:.3}s  converged = {}",
        res.solver,
        res.objective,
        res.nnz(),
        res.iters,
        res.seconds,
        res.converged
    );
}

fn cmd_gen_data(args: &Args) {
    let seed = args.usize_or("seed", 42) as u64;
    let ds = load_data(&args.get_or("data", "sparco:256x512:0.05"), seed);
    let out = args.get_or("out", "dataset.svm");
    let csr = ds.design.to_csr();
    let mut s = String::new();
    for i in 0..ds.n() {
        s.push_str(&format!("{}", ds.targets[i]));
        let (idx, val) = csr.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            s.push_str(&format!(" {}:{}", j + 1, v));
        }
        s.push('\n');
    }
    std::fs::write(&out, s).expect("write dataset");
    println!("wrote {} ({} x {}) to {out}", ds.name, ds.n(), ds.d());
}

fn cmd_info() {
    println!("shotgun repro build: {}", env!("CARGO_PKG_VERSION"));
    let art = Path::new("artifacts/manifest.json");
    if art.exists() {
        match shotgun::runtime::Manifest::load(art) {
            Ok(m) => {
                println!("artifacts: {} entries, profiles:", m.artifacts.len());
                for (tag, p) in &m.profiles {
                    println!("  {tag}: n={} d={} p={} k={}", p.n, p.d, p.p, p.k);
                }
            }
            Err(e) => println!("artifacts: manifest unreadable: {e}"),
        }
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    #[cfg(feature = "xla-pjrt")]
    match xla::PjRtClient::cpu() {
        Ok(c) => println!(
            "PJRT: platform {} with {} device(s)",
            c.platform_name(),
            c.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    #[cfg(not(feature = "xla-pjrt"))]
    println!("PJRT: not compiled in (build with --features xla-pjrt)");
}

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("solve") => {
            if let Err(e) = cmd_solve(&args) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Some("solvers") => cmd_solvers(),
        Some("serve") => {
            if let Err(e) = cmd_serve(&args) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Some("sim") => {
            if let Err(e) = cmd_sim(&args) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Some("estimate-pstar") => cmd_estimate_pstar(&args),
        Some("bench") => cmd_bench(&args),
        Some("xla-demo") => cmd_xla_demo(&args),
        Some("gen-data") => cmd_gen_data(&args),
        Some("info") => cmd_info(),
        Some("help") | None => println!("{HELP}"),
        Some(other) => {
            eprintln!("unknown command {other:?}\n{HELP}");
            std::process::exit(2);
        }
    }
}
