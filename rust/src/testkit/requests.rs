//! Seeded request-stream generation — the deterministic traffic source
//! behind `repro serve` and `tests/serving.rs`.
//!
//! A [`StreamSpec`] fully determines a stream: same spec + seed →
//! bit-identical requests, so a replay benchmark is reproducible across
//! machines and a failing serving test replays exactly. Streams
//! round-trip through the `repro serve --file` JSONL wire format via
//! [`to_jsonl`]/[`from_jsonl`].

use crate::api::serve::PredictRequest;
use crate::api::ShotgunError;
use crate::util::rng::Rng;

/// Shape of a synthetic request stream.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    /// Feature dimension requests index into (the model's `d`).
    pub d: usize,
    /// Number of requests.
    pub count: usize,
    /// Maximum nonzero features per request (actual count is uniform in
    /// `[1, max_nnz]`).
    pub max_nnz: usize,
    /// Fraction of requests flagged `proba` (logistic serving only —
    /// keep 0.0 for squared-loss models).
    pub proba_fraction: f64,
}

impl StreamSpec {
    /// A stream of `count` requests over `d` features with the default
    /// sparsity (up to 8 features per request, no proba).
    pub fn new(d: usize, count: usize) -> StreamSpec {
        StreamSpec {
            d,
            count,
            max_nnz: 8,
            proba_fraction: 0.0,
        }
    }
}

/// Generate the stream for `spec` from `seed` (deterministic; see the
/// module docs).
pub fn stream(spec: &StreamSpec, seed: u64) -> Vec<PredictRequest> {
    assert!(spec.d > 0, "request stream needs d >= 1");
    let mut rng = Rng::new(seed);
    let max_nnz = spec.max_nnz.clamp(1, spec.d);
    (0..spec.count)
        .map(|_| {
            let k = 1 + rng.below(max_nnz);
            let mut idx = rng.sample_without_replacement(spec.d, k);
            idx.sort_unstable();
            let features = idx
                .into_iter()
                .map(|j| (j as u32, rng.normal()))
                .collect();
            PredictRequest {
                features,
                proba: spec.proba_fraction > 0.0 && rng.bernoulli(spec.proba_fraction),
            }
        })
        .collect()
}

/// Serialize a stream as JSONL (one request per line — the
/// `repro serve --file` format).
pub fn to_jsonl(requests: &[PredictRequest]) -> String {
    let mut out = String::new();
    for req in requests {
        out.push_str(&req.to_json_line());
        out.push('\n');
    }
    out
}

/// Parse a JSONL stream (blank lines and `#` comment lines skipped);
/// errors carry the 1-based line number.
pub fn from_jsonl(text: &str) -> Result<Vec<PredictRequest>, ShotgunError> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match PredictRequest::from_json_line(line) {
            Ok(req) => out.push(req),
            Err(ShotgunError::BadRequest { reason, .. }) => {
                return Err(ShotgunError::BadRequest {
                    index: lineno + 1,
                    reason,
                })
            }
            Err(e) => return Err(e),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_deterministic_and_in_spec() {
        let spec = StreamSpec {
            d: 50,
            count: 200,
            max_nnz: 6,
            proba_fraction: 0.3,
        };
        let a = stream(&spec, 42);
        let b = stream(&spec, 42);
        assert_eq!(a, b, "same seed, same stream");
        assert_ne!(a, stream(&spec, 43), "different seed, different stream");
        assert_eq!(a.len(), 200);
        let mut saw_proba = false;
        for req in &a {
            assert!(!req.features.is_empty() && req.features.len() <= 6);
            // indices sorted, unique, in range
            for w in req.features.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
            assert!(req.features.iter().all(|&(j, v)| (j as usize) < 50 && v.is_finite()));
            saw_proba |= req.proba;
        }
        assert!(saw_proba, "proba_fraction 0.3 over 200 requests");
    }

    #[test]
    fn jsonl_roundtrip() {
        let spec = StreamSpec {
            d: 20,
            count: 30,
            max_nnz: 4,
            proba_fraction: 0.5,
        };
        let reqs = stream(&spec, 7);
        let text = to_jsonl(&reqs);
        let back = from_jsonl(&text).expect("parse");
        assert_eq!(back, reqs);
        // comments/blank lines tolerated, errors carry the line number
        let padded = format!("# header\n\n{text}");
        assert_eq!(from_jsonl(&padded).expect("parse"), reqs);
        let err = from_jsonl("{\"features\":[[0,1.0]]}\nnot json\n").unwrap_err();
        match err {
            ShotgunError::BadRequest { index, .. } => assert_eq!(index, 2),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn max_nnz_clamps_to_d() {
        let spec = StreamSpec {
            d: 3,
            count: 50,
            max_nnz: 100,
            proba_fraction: 0.0,
        };
        for req in stream(&spec, 1) {
            assert!(req.features.len() <= 3);
        }
    }
}
