//! Property-testing helper (proptest substitute — not in the vendored
//! crate set). Generates random cases from a seeded RNG, runs the
//! property, and on failure reports the seed + case index so the exact
//! case replays deterministically.

use crate::util::rng::Rng;

pub mod requests;

/// Run `cases` random property checks. `gen` builds a case from an RNG;
/// `prop` returns `Err(msg)` to fail. Panics with the replay coordinates.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Random dense column-normalized design + targets, the standard problem
/// generator for the coordinator property tests.
pub struct RandomLasso {
    pub n: usize,
    pub d: usize,
    pub a: crate::sparsela::Design,
    pub y: Vec<f64>,
    pub lam: f64,
}

impl std::fmt::Debug for RandomLasso {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RandomLasso(n={}, d={}, lam={:.4})",
            self.n, self.d, self.lam
        )
    }
}

/// Sample a random Lasso instance with n in [5, 40], d in [2, 30].
pub fn random_lasso(rng: &mut Rng) -> RandomLasso {
    let n = 5 + rng.below(36);
    let d = 2 + rng.below(29);
    let mut m = crate::sparsela::DenseMatrix::from_fn(n, d, |_, _| rng.normal());
    m.normalize_columns();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let lam = 0.01 + rng.uniform();
    RandomLasso {
        n,
        d,
        a: crate::sparsela::Design::Dense(m),
        y,
        lam,
    }
}

/// Random dense column-normalized design + ±1 labels, for the logistic
/// cross-loss property tests.
pub struct RandomLogistic {
    pub n: usize,
    pub d: usize,
    pub a: crate::sparsela::Design,
    pub y: Vec<f64>,
    pub lam: f64,
}

impl std::fmt::Debug for RandomLogistic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RandomLogistic(n={}, d={}, lam={:.4})",
            self.n, self.d, self.lam
        )
    }
}

/// Sample a random sparse-logistic instance with n in [8, 40], d in
/// [2, 30] and lambda small enough that solutions stay non-trivial.
pub fn random_logistic(rng: &mut Rng) -> RandomLogistic {
    let n = 8 + rng.below(33);
    let d = 2 + rng.below(29);
    let mut m = crate::sparsela::DenseMatrix::from_fn(n, d, |_, _| rng.normal());
    m.normalize_columns();
    let y: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    let lam = 0.01 + 0.2 * rng.uniform();
    RandomLogistic {
        n,
        d,
        a: crate::sparsela::Design::Dense(m),
        y,
        lam,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(
            "uniform-in-range",
            1,
            50,
            |rng| rng.uniform(),
            |&u| {
                if (0.0..1.0).contains(&u) {
                    Ok(())
                } else {
                    Err(format!("{u} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check(
            "always-fails",
            2,
            3,
            |rng| rng.below(10),
            |_| Err("boom".into()),
        );
    }

    #[test]
    fn random_lasso_shapes() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let c = random_lasso(&mut rng);
            assert_eq!(c.a.n(), c.n);
            assert_eq!(c.a.d(), c.d);
            assert_eq!(c.y.len(), c.n);
            assert!(c.lam > 0.0);
        }
    }

    #[test]
    fn random_logistic_shapes() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let c = random_logistic(&mut rng);
            assert_eq!(c.a.n(), c.n);
            assert_eq!(c.a.d(), c.d);
            assert!(c.y.iter().all(|&v| v == 1.0 || v == -1.0));
            assert!(c.lam > 0.0);
        }
    }
}
