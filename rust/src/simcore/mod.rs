//! Multicore memory-wall simulator — the §4.3 substitute for the paper's
//! 8-core Opteron testbed (this container has one core).
//!
//! The paper's own §4.3 analysis is the model: each Shotgun update makes
//! O(nnz_j) memory accesses with *no temporal locality* (every update
//! touches a different column), performs O(nnz_j) flops (O(1) flops per
//! access), and issues atomic updates to the shared `Ax` vector, so the
//! memory bus — not the ALUs — bounds throughput. We model per-update
//! wall time on a P-core machine as
//!
//!   t(P) = nnz_j * [ t_flop + t_mem * c(P) ] + t_atomic * nnz_j * a(P)
//!
//! where `c(P) = 1 + beta_bw (P-1)` captures bandwidth contention and
//! `a(P) = 1 + beta_cas (P-1)` captures CAS retries/cacheline pingpong.
//! P workers run concurrently, so a round of P updates costs
//! `max_j t(P)` (synchronous) or throughput `P / t(P)` (asynchronous).
//!
//! Defaults are calibrated so the time-speedup at P = 8 lands in the
//! paper's observed 2–4x band while iteration-speedup stays ~8x
//! (Fig. 5a/c vs 5b/d). EXPERIMENTS.md records the calibration.

/// Cost-model parameters (seconds). Defaults approximate a 2.7 GHz
/// Opteron-era core: ~1 ns per fused flop step, ~2 ns per uncached
/// double fetched over the bus, ~8 ns per contended atomic.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub t_flop: f64,
    pub t_mem: f64,
    pub t_atomic: f64,
    /// Marginal bandwidth contention per extra core.
    pub beta_bw: f64,
    /// Marginal CAS contention per extra core.
    pub beta_cas: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            t_flop: 1.0e-9,
            t_mem: 2.0e-9,
            t_atomic: 8.0e-9,
            beta_bw: 0.35,
            beta_cas: 0.15,
        }
    }
}

impl CostModel {
    /// Simulated seconds for ONE coordinate update touching `nnz`
    /// residual entries on a machine running `p` concurrent workers.
    pub fn update_seconds(&self, nnz: usize, p: usize) -> f64 {
        let c = 1.0 + self.beta_bw * (p.saturating_sub(1)) as f64;
        let a = 1.0 + self.beta_cas * (p.saturating_sub(1)) as f64;
        // read column + read residual (2 streams) + flops + atomic writes
        nnz as f64 * (self.t_flop + 2.0 * self.t_mem * c + self.t_atomic * a)
    }

    /// Simulated seconds for a synchronous round of `p` updates with the
    /// given per-update nnz counts: the slowest update gates the round.
    pub fn round_seconds(&self, nnzs: &[usize], p: usize) -> f64 {
        nnzs.iter()
            .map(|&z| self.update_seconds(z, p))
            .fold(0.0, f64::max)
    }

    /// Simulated seconds for `total_updates` asynchronous updates of
    /// average size `avg_nnz` spread over `p` workers (steady-state
    /// throughput model).
    pub fn async_seconds(&self, total_updates: u64, avg_nnz: f64, p: usize) -> f64 {
        let per = self.update_seconds(avg_nnz.round() as usize, p);
        per * total_updates as f64 / p as f64
    }

    /// Predicted time-speedup of `p` cores over 1 core at fixed work
    /// (the Fig. 5a/c curve shape).
    pub fn time_speedup(&self, avg_nnz: f64, p: usize) -> f64 {
        self.async_seconds(1_000_000, avg_nnz, 1) / self.async_seconds(1_000_000, avg_nnz, p)
    }
}

/// A simulated clock accumulated alongside a real solve.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub seconds: f64,
}

impl SimClock {
    pub fn charge_round(&mut self, model: &CostModel, nnzs: &[usize], p: usize) {
        self.seconds += model.round_seconds(nnzs, p);
    }

    pub fn charge_async(&mut self, model: &CostModel, updates: u64, avg_nnz: f64, p: usize) {
        self.seconds += model.async_seconds(updates, avg_nnz, p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_cost_scales_with_nnz() {
        let m = CostModel::default();
        assert!(m.update_seconds(100, 1) > 9.0 * m.update_seconds(10, 1));
    }

    #[test]
    fn contention_grows_with_p() {
        let m = CostModel::default();
        assert!(m.update_seconds(50, 8) > m.update_seconds(50, 1));
    }

    #[test]
    fn speedup_in_paper_band_at_8_cores() {
        // the calibration target: Fig. 5 sees 2-4x time speedup at P = 8
        let m = CostModel::default();
        let s8 = m.time_speedup(100.0, 8);
        assert!(
            (2.0..=4.5).contains(&s8),
            "8-core simulated speedup {s8} outside the paper's band"
        );
        // and speedup must be monotone in P
        let s2 = m.time_speedup(100.0, 2);
        let s4 = m.time_speedup(100.0, 4);
        assert!(s2 > 1.0 && s4 > s2 && s8 > s4);
    }

    #[test]
    fn sync_round_gated_by_slowest() {
        let m = CostModel::default();
        let r = m.round_seconds(&[10, 10, 500, 10], 4);
        assert_eq!(r, m.update_seconds(500, 4));
    }

    #[test]
    fn clock_accumulates() {
        let m = CostModel::default();
        let mut c = SimClock::default();
        c.charge_round(&m, &[10, 20], 2);
        c.charge_async(&m, 100, 15.0, 2);
        assert!(c.seconds > 0.0);
    }
}
