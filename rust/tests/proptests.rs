//! Property-based tests on the coordinator invariants (testkit::check is
//! the proptest substitute — see DESIGN.md §Environment-substitutions).

use shotgun::coordinator::{ActiveSet, ShotgunConfig, ShotgunExact, ShrinkConfig};
use shotgun::objective::{LassoProblem, LogisticProblem};
use shotgun::sparsela::{power, vecops, CscMatrix, Design, DenseMatrix};
use shotgun::solvers::common::{LassoSolver as _, LogisticSolver as _, SolveOptions};
use shotgun::solvers::shooting::Shooting;
use shotgun::testkit::{check, random_lasso, random_logistic};
use shotgun::util::rng::Rng;

#[test]
fn prop_residual_cache_matches_fresh_residual() {
    // after any number of Shotgun rounds at any P, the engine's carried
    // residual equals A x - y recomputed from scratch
    check(
        "residual-cache",
        11,
        25,
        random_lasso,
        |case| {
            let prob = LassoProblem::new(&case.a, &case.y, case.lam);
            let mut rng = Rng::new(3);
            let p = 1 + rng.below(6);
            let engine = ShotgunExact::new(ShotgunConfig {
                p,
                ..Default::default()
            });
            let mut x = vec![0.0; case.d];
            let mut r = prob.residual(&x);
            let mut draws = Vec::new();
            let mut deltas = Vec::new();
            for _ in 0..30 {
                engine.lasso_round(&prob, &mut x, &mut r, &mut rng, &mut draws, &mut deltas);
            }
            let fresh = prob.residual(&x);
            for (c, f) in r.iter().zip(&fresh) {
                if (c - f).abs() > 1e-8 {
                    return Err(format!("cache {c} vs fresh {f}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_p1_identical_to_shooting() {
    // Shotgun with P = 1 must be bit-identical to Shooting (same RNG)
    check(
        "p1-is-shooting",
        13,
        15,
        random_lasso,
        |case| {
            let prob = LassoProblem::new(&case.a, &case.y, case.lam);
            let opts = SolveOptions {
                max_iters: 500,
                tol: 1e-12,
                record_every: u64::MAX,
                seed: 5,
                ..Default::default()
            };
            let a = ShotgunExact::new(ShotgunConfig {
                p: 1,
                ..Default::default()
            })
            .solve_lasso(&prob, &vec![0.0; case.d], &opts);
            let b = Shooting.solve_lasso(&prob, &vec![0.0; case.d], &opts);
            if a.x != b.x {
                return Err("P=1 trajectory diverged from Shooting".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_converged_solutions_satisfy_kkt() {
    check(
        "kkt-at-convergence",
        17,
        15,
        random_lasso,
        |case| {
            let prob = LassoProblem::new(&case.a, &case.y, case.lam);
            let opts = SolveOptions {
                max_iters: 400_000,
                tol: 1e-9,
                record_every: u64::MAX,
                seed: 7,
                ..Default::default()
            };
            let res = ShotgunExact::new(ShotgunConfig {
                p: 2,
                ..Default::default()
            })
            .solve_lasso(&prob, &vec![0.0; case.d], &opts);
            if !res.converged {
                return Ok(()); // budget-bound, not a property violation
            }
            let r = prob.residual(&res.x);
            let kkt = prob.kkt_violation(&res.x, &r);
            if kkt > 1e-6 {
                return Err(format!("kkt {kkt} at converged solution"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_objective_never_nan_even_at_huge_p() {
    // divergence must be detected and reported, never silently NaN
    check(
        "divergence-detected",
        19,
        10,
        random_lasso,
        |case| {
            let prob = LassoProblem::new(&case.a, &case.y, case.lam);
            let opts = SolveOptions {
                max_iters: 3_000,
                tol: 1e-9,
                record_every: 64,
                seed: 9,
                ..Default::default()
            };
            let res = ShotgunExact::new(ShotgunConfig {
                p: case.d, // way past P* for correlated cases
                ..Default::default()
            })
            .solve_lasso(&prob, &vec![0.0; case.d], &opts);
            for pt in &res.trace.points {
                if pt.objective.is_nan() {
                    return Err("NaN escaped into the trace".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_power_iteration_matches_jacobi() {
    check(
        "rho-estimation",
        23,
        12,
        random_lasso,
        |case| {
            let est = power::spectral_radius(&case.a, 5000, 1e-13, 1).rho;
            let exact = power::spectral_radius_exact(&case.a);
            if (est - exact).abs() / exact.max(1e-12) > 1e-3 {
                return Err(format!("power {est} vs jacobi {exact}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csc_roundtrip_and_validate() {
    check(
        "csc-roundtrip",
        29,
        30,
        |rng| {
            let n = 1 + rng.below(30);
            let d = 1 + rng.below(30);
            let mut trip = Vec::new();
            for j in 0..d {
                for i in 0..n {
                    if rng.bernoulli(0.2) {
                        trip.push((i, j, rng.normal()));
                    }
                }
            }
            (n, d, trip)
        },
        |(n, d, trip)| {
            let m = CscMatrix::from_triplets(*n, *d, trip);
            m.validate().map_err(|e| format!("validate: {e}"))?;
            let dense = m.to_dense();
            let back = CscMatrix::from_dense(&dense);
            if back != m {
                return Err("dense roundtrip changed the matrix".into());
            }
            // matvec agreement with the dense path
            let mut rng = Rng::new(1);
            let x: Vec<f64> = (0..*d).map(|_| rng.normal()).collect();
            let mut ys = vec![0.0; *n];
            let mut yd = vec![0.0; *n];
            m.matvec(&x, &mut ys);
            dense.matvec(&x, &mut yd);
            for (a, b) in ys.iter().zip(&yd) {
                if (a - b).abs() > 1e-10 {
                    return Err("matvec mismatch".into());
                }
            }
            Ok(())
        },
    );
}

/// Transcription of the PRE-refactor per-loss `Shooting::solve_lasso`
/// body (inherent problem methods + scheduler only, no `CdObjective`):
/// the regression oracle for the generic `solve_cd` path.
fn reference_shooting_lasso(prob: &LassoProblem, opts: &SolveOptions) -> Vec<f64> {
    let d = prob.d();
    let mut rng = Rng::new(opts.seed);
    let mut x = vec![0.0; d];
    let mut r = prob.residual(&x);
    let shrink = opts.shrink.enabled;
    let thr = opts.shrink.threshold(prob.lam);
    let mut active = ActiveSet::full(d);
    let mut window_max: f64 = 0.0;
    let mut iter = 0u64;
    while iter < opts.max_iters {
        if active.is_empty() {
            if active.recheck_full(opts.tol, |k| prob.cd_step(k, x[k], &r)) < opts.tol {
                break;
            }
            continue;
        }
        iter += 1;
        let j = active.draw(&mut rng);
        let (g, dx) = prob.cd_update(j, &mut x, &mut r);
        window_max = window_max.max(dx.abs());
        if shrink && dx == 0.0 && x[j] == 0.0 && g.abs() < thr {
            active.prune(j);
        }
        if iter % d as u64 == 0 {
            if window_max < opts.tol
                && active.recheck_full(opts.tol, |k| prob.cd_step(k, x[k], &r)) < opts.tol
            {
                break;
            }
            window_max = 0.0;
        }
    }
    x
}

/// Pre-refactor per-loss `Shooting::solve_logistic` body (split
/// grad → step → apply sequence over the margin cache).
fn reference_shooting_logistic(prob: &LogisticProblem, opts: &SolveOptions) -> Vec<f64> {
    let d = prob.d();
    let mut rng = Rng::new(opts.seed);
    let mut x = vec![0.0; d];
    let mut z = prob.margins(&x);
    let shrink = opts.shrink.enabled;
    let thr = opts.shrink.threshold(prob.lam);
    let mut active = ActiveSet::full(d);
    let mut window_max: f64 = 0.0;
    let mut iter = 0u64;
    while iter < opts.max_iters {
        if active.is_empty() {
            if active.recheck_full(opts.tol, |k| prob.cd_step(k, x[k], &z)) < opts.tol {
                break;
            }
            continue;
        }
        iter += 1;
        let j = active.draw(&mut rng);
        let g = prob.grad_j(j, &z);
        let dx = prob.cd_step_from_g(j, x[j], g);
        prob.apply_step(j, dx, &mut x, &mut z);
        window_max = window_max.max(dx.abs());
        if shrink && dx == 0.0 && x[j] == 0.0 && g.abs() < thr {
            active.prune(j);
        }
        if iter % d as u64 == 0 {
            if window_max < opts.tol
                && active.recheck_full(opts.tol, |k| prob.cd_step(k, x[k], &z)) < opts.tol
            {
                break;
            }
            window_max = 0.0;
        }
    }
    x
}

#[test]
fn prop_generic_lasso_bit_identical_to_per_loss_reference() {
    // the multi-layer refactor's contract: the generic solve_cd path is
    // BIT-identical to the pre-refactor per-loss loop on seeded problems
    check(
        "generic-lasso-bit-identity",
        47,
        15,
        random_lasso,
        |case| {
            let prob = LassoProblem::new(&case.a, &case.y, case.lam);
            let opts = SolveOptions {
                max_iters: 4_000,
                tol: 1e-10,
                record_every: u64::MAX,
                seed: 9,
                ..Default::default()
            };
            let generic = Shooting.solve_lasso(&prob, &vec![0.0; case.d], &opts);
            let reference = reference_shooting_lasso(&prob, &opts);
            for (j, (a, b)) in generic.x.iter().zip(&reference).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("x[{j}] differs: generic {a} vs reference {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_generic_logistic_bit_identical_to_per_loss_reference() {
    check(
        "generic-logistic-bit-identity",
        53,
        15,
        random_logistic,
        |case| {
            let prob = LogisticProblem::new(&case.a, &case.y, case.lam);
            let opts = SolveOptions {
                max_iters: 4_000,
                tol: 1e-10,
                record_every: u64::MAX,
                seed: 11,
                ..Default::default()
            };
            let generic = Shooting.solve_logistic(&prob, &vec![0.0; case.d], &opts);
            let reference = reference_shooting_logistic(&prob, &opts);
            for (j, (a, b)) in generic.x.iter().zip(&reference).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("x[{j}] differs: generic {a} vs reference {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_strong_rules_never_lose_a_support_coordinate() {
    // sequential strong rules screen coordinates per path stage; the
    // engines' full KKT recheck must rescue every wrongly pruned one —
    // so a coordinate that is nonzero at the direct optimum can never
    // end the strong-rules path pruned-and-zero
    use shotgun::solvers::path::{solve_path_lasso, strong_rule_keep, PathConfig};
    let mut screened_total = 0usize;
    check(
        "strong-rules-support-safe",
        59,
        10,
        random_lasso,
        |case| {
            let lam_max = LassoProblem::new(&case.a, &case.y, 0.0).lambda_max();
            let lam = (0.15 * lam_max).max(1e-6);
            let opts = SolveOptions {
                max_iters: 400_000,
                tol: 1e-9,
                record_every: u64::MAX,
                seed: 13,
                ..Default::default()
            };
            let strong = solve_path_lasso(
                &case.a,
                &case.y,
                lam,
                &PathConfig {
                    stages: 5,
                    strong_rules: true,
                },
                &opts,
                |p, x0, o| Shooting.solve_lasso(p, x0, o),
            );
            let direct = {
                let prob = LassoProblem::new(&case.a, &case.y, lam);
                Shooting.solve_lasso(&prob, &vec![0.0; case.d], &opts)
            };
            if !(strong.converged && direct.converged) {
                return Ok(()); // budget-bound, not a property violation
            }
            let prob = LassoProblem::new(&case.a, &case.y, lam);
            let r = prob.residual(&strong.x);
            let kkt = prob.kkt_violation(&strong.x, &r);
            if kkt > 1e-5 {
                return Err(format!("kkt {kkt} at the strong-rules solution"));
            }
            let gap = (strong.objective - direct.objective).abs()
                / direct.objective.abs().max(1e-12);
            if gap > 1e-3 {
                return Err(format!(
                    "strong rules moved the optimum: {} vs {} (gap {gap:.2e})",
                    strong.objective, direct.objective
                ));
            }
            // every solid support coordinate of the direct optimum must
            // survive in the strong-rules solution
            for j in 0..case.d {
                if direct.x[j].abs() > 1e-5 && strong.x[j] == 0.0 {
                    return Err(format!(
                        "support coordinate {j} (direct x={}) ended pruned-and-zero",
                        direct.x[j]
                    ));
                }
            }
            // accounting: make sure screening actually engages somewhere
            // across the case set (otherwise this test is vacuous)
            let mid = LassoProblem::new(&case.a, &case.y, lam * 1.5);
            let warm = Shooting.solve_lasso(&mid, &vec![0.0; case.d], &opts);
            let keep = strong_rule_keep(&prob, &warm.x, lam, lam * 1.5);
            screened_total += case.d - keep.len();
            Ok(())
        },
    );
    assert!(
        screened_total > 0,
        "strong rule screened nothing across all cases — test is vacuous"
    );
}

#[test]
fn prop_pathwise_matches_direct_optimum() {
    // warm starts never end meaningfully worse than the cold start
    check(
        "pathwise-warm-start",
        31,
        8,
        random_lasso,
        |case| {
            use shotgun::solvers::path::solve_pathwise;
            let prob0 = LassoProblem::new(&case.a, &case.y, 0.0);
            let lam_max = prob0.lambda_max();
            let lam = (0.1 * lam_max).max(1e-6);
            let opts = SolveOptions {
                max_iters: 300_000,
                tol: 1e-9,
                record_every: u64::MAX,
                seed: 3,
                ..Default::default()
            };
            let path = solve_pathwise(lam_max, lam, 4, case.d, &opts, |l, x0, o| {
                let prob = LassoProblem::new(&case.a, &case.y, l);
                Shooting.solve_lasso(&prob, x0, o)
            });
            let direct = {
                let prob = LassoProblem::new(&case.a, &case.y, lam);
                Shooting.solve_lasso(&prob, &vec![0.0; case.d], &opts)
            };
            let rel = (path.objective - direct.objective).abs()
                / direct.objective.abs().max(1e-12);
            if rel > 1e-2 {
                return Err(format!(
                    "pathwise {} vs direct {}",
                    path.objective, direct.objective
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shrinking_never_changes_the_optimum() {
    // the scheduler's promise: with the full-sweep KKT recheck guarding
    // convergence, active-set shrinking returns the same optimum as the
    // unshrunk path — on sparse AND dense designs, for the sequential
    // and the parallel engine alike
    check(
        "shrink-invariant-optimum",
        41,
        12,
        |rng| {
            let n = 20 + rng.below(30);
            let d = 10 + rng.below(40);
            let a = if rng.bernoulli(0.5) {
                let mut trip = Vec::new();
                for j in 0..d {
                    // guarantee non-empty columns
                    trip.push((rng.below(n), j, rng.normal()));
                    for i in 0..n {
                        if rng.bernoulli(0.15) {
                            trip.push((i, j, rng.normal()));
                        }
                    }
                }
                let mut m = CscMatrix::from_triplets(n, d, &trip);
                m.normalize_columns();
                Design::Sparse(m)
            } else {
                let mut m = DenseMatrix::from_fn(n, d, |_, _| rng.normal());
                m.normalize_columns();
                Design::Dense(m)
            };
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let lam = 0.05 + 0.5 * rng.uniform();
            (a, y, lam)
        },
        |(a, y, lam)| {
            let prob = LassoProblem::new(a, y, *lam);
            let d = a.d();
            let opts_on = SolveOptions {
                max_iters: 400_000,
                tol: 1e-7,
                record_every: u64::MAX,
                seed: 3,
                ..Default::default()
            };
            let opts_off = SolveOptions {
                shrink: ShrinkConfig::disabled(),
                ..opts_on.clone()
            };
            let on = Shooting.solve_lasso(&prob, &vec![0.0; d], &opts_on);
            let off = Shooting.solve_lasso(&prob, &vec![0.0; d], &opts_off);
            if !(on.converged && off.converged) {
                return Ok(()); // budget-bound, not a property violation
            }
            for (tag, res) in [("on", &on), ("off", &off)] {
                let r = prob.residual(&res.x);
                let kkt = prob.kkt_violation(&res.x, &r);
                if kkt > 1e-4 {
                    return Err(format!("kkt {kkt} at optimum with shrink {tag}"));
                }
            }
            let gap = (on.objective - off.objective).abs() / off.objective.abs().max(1e-12);
            if gap > 1e-3 {
                return Err(format!(
                    "shrinking moved the optimum: on {} vs off {} (gap {gap:.2e})",
                    on.objective, off.objective
                ));
            }
            // parallel engine, same invariant
            let par = ShotgunExact::new(ShotgunConfig {
                p: 2,
                ..Default::default()
            })
            .solve_lasso(&prob, &vec![0.0; d], &opts_on);
            if par.converged {
                let r = prob.residual(&par.x);
                let kkt = prob.kkt_violation(&par.x, &r);
                if kkt > 1e-4 {
                    return Err(format!("parallel kkt {kkt} with shrinking"));
                }
                let gap =
                    (par.objective - off.objective).abs() / off.objective.abs().max(1e-12);
                if gap > 1e-3 {
                    return Err(format!(
                        "parallel shrinking moved the optimum (gap {gap:.2e})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_col_dot_axpy_bit_exact() {
    // the fused kernel must equal col_dot followed by col_axpy
    // bit-for-bit on arbitrary CSC matrices (shared gather/scatter
    // kernels make this exact, not approximate)
    check(
        "fused-kernel-bit-exact",
        43,
        30,
        |rng| {
            let n = 1 + rng.below(60);
            let d = 1 + rng.below(20);
            let mut trip = Vec::new();
            for j in 0..d {
                for i in 0..n {
                    if rng.bernoulli(0.3) {
                        trip.push((i, j, rng.normal()));
                    }
                }
            }
            let m = CscMatrix::from_triplets(n, d, &trip);
            let r: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let scale = rng.normal();
            (m, r, scale)
        },
        |(m, r, scale)| {
            for j in 0..m.d {
                let mut r_fused = r.clone();
                let mut r_split = r.clone();
                let (g1, s1) = m.col_dot_axpy(j, &mut r_fused, |g| scale * g);
                let g2 = m.col_dot(j, &r_split);
                let s2 = scale * g2;
                if s2 != 0.0 {
                    m.col_axpy(j, s2, &mut r_split);
                }
                if g1.to_bits() != g2.to_bits() || s1.to_bits() != s2.to_bits() {
                    return Err(format!("(g, s) mismatch at column {j}: {g1} vs {g2}"));
                }
                for (i, (a, b)) in r_fused.iter().zip(&r_split).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("residual bit mismatch at ({i}, col {j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_column_scaling_invariance() {
    // footnote 1: normalization does not change the objective when the
    // scaled design is re-normalized (sanity on the generator pipeline)
    check(
        "normalization-invariance",
        37,
        10,
        |rng| {
            let n = 10 + rng.below(20);
            let d = 2 + rng.below(10);
            let mut m = shotgun::sparsela::DenseMatrix::from_fn(n, d, |_, _| rng.normal());
            m.normalize_columns();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (m, y)
        },
        |(m, y)| {
            let a = Design::Dense(m.clone());
            let prob = LassoProblem::new(&a, y, 0.3);
            let opts = SolveOptions {
                max_iters: 200_000,
                tol: 1e-10,
                record_every: u64::MAX,
                ..Default::default()
            };
            let res = Shooting.solve_lasso(&prob, &vec![0.0; m.d], &opts);
            // scale columns by 2 then re-normalize: identical problem
            let mut m2 =
                shotgun::sparsela::DenseMatrix::from_fn(m.n, m.d, |i, j| 2.0 * m.get(i, j));
            let norms = m2.normalize_columns();
            for &nrm in &norms {
                if (nrm - 2.0).abs() > 1e-9 {
                    return Err("scaling setup broken".into());
                }
            }
            let a2 = Design::Dense(m2);
            let prob2 = LassoProblem::new(&a2, y, 0.3);
            let res2 = Shooting.solve_lasso(&prob2, &vec![0.0; m.d], &opts);
            for (u, v) in res.x.iter().zip(&res2.x) {
                if (u - v).abs() > 1e-6 {
                    return Err(format!("normalized solutions differ: {u} vs {v}"));
                }
            }
            let _ = vecops::norm1(&res.x);
            Ok(())
        },
    );
}
