//! Cross-validation integration tests: every Lasso solver must land on
//! the same optimum as every other on shared instances across dataset
//! categories — the apples-to-apples guarantee behind Fig. 3.

use shotgun::coordinator::{Engine, Shotgun, ShotgunConfig};
use shotgun::data::synth;
use shotgun::objective::{LassoProblem, LogisticProblem};
use shotgun::solvers::common::{LassoSolver, LogisticSolver, SolveOptions};
use shotgun::solvers::{
    cdn::ShootingCdn, fpc_as::FpcAs, gpsr_bb::GpsrBb, l1_ls::L1Ls, shooting::Shooting,
    sparsa::Sparsa,
};

fn opts() -> SolveOptions {
    SolveOptions {
        max_iters: 500_000,
        tol: 1e-9,
        record_every: 1024,
        seed: 5,
        ..Default::default()
    }
}

fn lasso_optima(ds: &shotgun::data::Dataset, lam: f64) -> Vec<(String, f64)> {
    let d = ds.d();
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let x0 = vec![0.0; d];
    let o = opts();
    let mut out: Vec<(String, f64)> = Vec::new();
    out.push((
        "shooting".into(),
        Shooting.solve_lasso(&prob, &x0, &o).objective,
    ));
    out.push((
        "shotgun-p4".into(),
        Shotgun::new(ShotgunConfig {
            p: 4,
            ..Default::default()
        })
        .solve_lasso(&prob, &x0, &o)
        .objective,
    ));
    out.push((
        "shotgun-threaded-p2".into(),
        Shotgun::new(ShotgunConfig {
            p: 2,
            engine: Engine::Threaded,
            ..Default::default()
        })
        .solve_lasso(&prob, &x0, &o)
        .objective,
    ));
    out.push((
        "l1-ls".into(),
        L1Ls::default().solve_lasso(&prob, &x0, &o).objective,
    ));
    out.push((
        "fpc-as".into(),
        FpcAs::default()
            .solve_lasso(&prob, &x0, &SolveOptions {
                max_iters: 5_000,
                ..o.clone()
            })
            .objective,
    ));
    out.push((
        "gpsr-bb".into(),
        GpsrBb::default().solve_lasso(&prob, &x0, &o).objective,
    ));
    out.push((
        "sparsa".into(),
        Sparsa::default().solve_lasso(&prob, &x0, &o).objective,
    ));
    out
}

fn assert_consensus(tag: &str, optima: &[(String, f64)], rel: f64) {
    let best = optima.iter().map(|(_, f)| *f).fold(f64::INFINITY, f64::min);
    for (name, f) in optima {
        assert!(
            (f - best).abs() / best.abs().max(1e-12) < rel,
            "{tag}: {name} landed at {f}, consensus best {best}"
        );
    }
}

#[test]
fn lasso_consensus_sparco() {
    let ds = synth::sparco_like(64, 48, 0.3, 11);
    assert_consensus("sparco", &lasso_optima(&ds, 0.3), 1e-3);
}

#[test]
fn lasso_consensus_singlepix() {
    let ds = synth::singlepix_pm1(64, 48, 12);
    assert_consensus("singlepix", &lasso_optima(&ds, 0.5), 1e-3);
}

#[test]
fn lasso_consensus_imaging() {
    let ds = synth::sparse_imaging(64, 128, 0.08, 13);
    assert_consensus("imaging", &lasso_optima(&ds, 0.2), 1e-3);
}

#[test]
fn lasso_consensus_text() {
    let ds = synth::large_sparse_text(96, 80, 14);
    assert_consensus("text", &lasso_optima(&ds, 0.3), 1e-3);
}

#[test]
fn logistic_consensus() {
    // CD, CDN and parallel CDN agree on the logistic optimum
    let ds = synth::rcv1_like(80, 60, 0.2, 15);
    let prob = LogisticProblem::new(&ds.design, &ds.targets, 0.05);
    let x0 = vec![0.0; 60];
    let o = SolveOptions {
        max_iters: 300_000,
        tol: 1e-8,
        record_every: 1024,
        seed: 5,
        ..Default::default()
    };
    let cdn_o = SolveOptions {
        max_iters: 3_000,
        ..o.clone()
    };
    let optima = vec![
        (
            "shooting".to_string(),
            Shooting.solve_logistic(&prob, &x0, &o).objective,
        ),
        (
            "shooting-cdn".to_string(),
            ShootingCdn::default()
                .solve_logistic(&prob, &x0, &cdn_o)
                .objective,
        ),
        (
            "shotgun-cdn-p4".to_string(),
            shotgun::coordinator::ShotgunCdn::with_p(4)
                .solve_logistic(&prob, &x0, &o)
                .objective,
        ),
    ];
    assert_consensus("logistic", &optima, 1e-2);
}

#[test]
fn warm_start_cross_solver() {
    // a solution from one solver warm-starts another without regression
    let ds = synth::sparse_imaging(48, 96, 0.1, 16);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.15);
    let o = opts();
    let a = GpsrBb::default().solve_lasso(&prob, &vec![0.0; 96], &o);
    let b = Shooting.solve_lasso(&prob, &a.x, &o);
    assert!(b.objective <= a.objective + 1e-10);
    let c = Sparsa::default().solve_lasso(&prob, &b.x, &o);
    assert!(c.objective <= b.objective + 1e-10);
}
