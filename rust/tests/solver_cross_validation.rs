//! Cross-validation integration tests: every registered solver that
//! claims `exact_optimum` must land on the same optimum as every other
//! on shared instances across dataset categories — the apples-to-apples
//! guarantee behind Fig. 3. The solver set is enumerated from
//! `api::SolverRegistry` (no hand-rolled lists), so registering a new
//! exact solver automatically adds it to the consensus.

use shotgun::api::{IterUnit, ProblemRef, SolverParams, SolverRegistry};
use shotgun::data::synth;
use shotgun::objective::{LassoProblem, LogisticProblem, Loss};
use shotgun::solvers::common::{LassoSolver, SolveOptions};
use shotgun::solvers::{gpsr_bb::GpsrBb, shooting::Shooting, sparsa::Sparsa};

/// Budget sized to the solver's iteration unit: update-denominated
/// solvers need hundreds of thousands of draws, sweep-structured ones a
/// few thousand outer passes.
fn opts_for(unit: IterUnit, tol: f64) -> SolveOptions {
    let max_iters = match unit {
        IterUnit::Update | IterUnit::Round => 500_000,
        IterUnit::Sweep => 5_000,
        IterUnit::Epoch => 200,
    };
    SolveOptions {
        max_iters,
        tol,
        record_every: 1024,
        seed: 5,
        ..Default::default()
    }
}

fn lasso_optima(ds: &shotgun::data::Dataset, lam: f64) -> Vec<(String, f64)> {
    let registry = SolverRegistry::global();
    let d = ds.d();
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let x0 = vec![0.0; d];
    let params = SolverParams {
        p: 2,
        ..Default::default()
    };
    registry
        .entries()
        .iter()
        .filter(|e| e.caps.supports(Loss::Squared) && e.caps.exact_optimum)
        .map(|e| {
            let res = e
                .create(&params)
                .solve(
                    ProblemRef::Lasso(&prob),
                    &x0,
                    &opts_for(e.caps.iter_unit, 1e-9),
                )
                .expect("capability-gated");
            (e.name.to_string(), res.objective)
        })
        .collect()
}

fn assert_consensus(tag: &str, optima: &[(String, f64)], rel: f64) {
    assert!(
        optima.len() >= 7,
        "{tag}: consensus set shrank to {}",
        optima.len()
    );
    let best = optima.iter().map(|(_, f)| *f).fold(f64::INFINITY, f64::min);
    for (name, f) in optima {
        assert!(
            (f - best).abs() / best.abs().max(1e-12) < rel,
            "{tag}: {name} landed at {f}, consensus best {best}"
        );
    }
}

#[test]
fn lasso_consensus_sparco() {
    let ds = synth::sparco_like(64, 48, 0.3, 11);
    assert_consensus("sparco", &lasso_optima(&ds, 0.3), 1e-3);
}

#[test]
fn lasso_consensus_singlepix() {
    let ds = synth::singlepix_pm1(64, 48, 12);
    assert_consensus("singlepix", &lasso_optima(&ds, 0.5), 1e-3);
}

#[test]
fn lasso_consensus_imaging() {
    let ds = synth::sparse_imaging(64, 128, 0.08, 13);
    assert_consensus("imaging", &lasso_optima(&ds, 0.2), 1e-3);
}

#[test]
fn lasso_consensus_text() {
    let ds = synth::large_sparse_text(96, 80, 14);
    assert_consensus("text", &lasso_optima(&ds, 0.3), 1e-3);
}

#[test]
fn logistic_consensus() {
    // every exact-optimum logistic solver in the registry agrees
    let registry = SolverRegistry::global();
    let ds = synth::rcv1_like(80, 60, 0.2, 15);
    let prob = LogisticProblem::new(&ds.design, &ds.targets, 0.05);
    let x0 = vec![0.0; 60];
    let params = SolverParams {
        p: 2,
        ..Default::default()
    };
    let optima: Vec<(String, f64)> = registry
        .entries()
        .iter()
        .filter(|e| e.caps.supports(Loss::Logistic) && e.caps.exact_optimum)
        .map(|e| {
            let res = e
                .create(&params)
                .solve(
                    ProblemRef::Logistic(&prob),
                    &x0,
                    &opts_for(e.caps.iter_unit, 1e-8),
                )
                .expect("capability-gated");
            (e.name.to_string(), res.objective)
        })
        .collect();
    assert!(
        optima.len() >= 6,
        "logistic consensus set shrank to {}",
        optima.len()
    );
    let best = optima.iter().map(|(_, f)| *f).fold(f64::INFINITY, f64::min);
    for (name, f) in &optima {
        assert!(
            (f - best).abs() / best.abs().max(1e-12) < 1e-2,
            "logistic: {name} landed at {f}, consensus best {best}"
        );
    }
}

#[test]
fn warm_start_cross_solver() {
    // a solution from one solver warm-starts another without regression
    let ds = synth::sparse_imaging(48, 96, 0.1, 16);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.15);
    let o = SolveOptions {
        max_iters: 500_000,
        tol: 1e-9,
        record_every: 1024,
        seed: 5,
        ..Default::default()
    };
    let a = GpsrBb::default().solve_lasso(&prob, &vec![0.0; 96], &o);
    let b = Shooting.solve_lasso(&prob, &a.x, &o);
    assert!(b.objective <= a.objective + 1e-10);
    let c = Sparsa::default().solve_lasso(&prob, &b.x, &o);
    assert!(c.objective <= b.objective + 1e-10);
}
