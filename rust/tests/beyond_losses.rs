//! Beyond-paper loss acceptance tests: squared hinge + Huber through
//! every layer.
//!
//! 1. **Bit-identity**: solving through the erased registry handle (and
//!    the `Fit` front door) must reproduce the engines' generic
//!    `solve_cd` called directly — same seed, same options, same bits —
//!    for every deterministic solver advertising the loss. The direct
//!    side is hand-constructed, like `tests/api_redesign.rs`'s legacy
//!    tables.
//! 2. **Fixture optimum**: `Engine::Auto` and the pathwise strong-rules
//!    orchestrator land on the independent numpy reference optimum
//!    (`rust/tests/fixtures/{sqhinge,huber}_*.json`) within 1e-4
//!    relative — the per-solver sweep lives in
//!    `tests/golden_fixtures.rs`.
//! 3. **Pathwise for free**: strong-rule screening engages on a sparse
//!    instance of each new loss (solver tag gains `+path-strong`)
//!    without moving the optimum.
//! 4. **Serving**: `FitQueue` jobs fit/publish the new losses, the
//!    replay harness serves them, the model JSON round-trips
//!    bit-exactly, and proba requests against a sqhinge model are
//!    refused.

use shotgun::api::serve::{replay, FitJob, FitQueue, JobState, ModelStore, ReplayConfig};
use shotgun::api::{Engine, Fit, Model, PathSpec, ProblemRef, SolverParams, SolverRegistry};
use shotgun::coordinator::{Shotgun, ShotgunCdn, ShotgunConfig};
use shotgun::data::synth;
use shotgun::objective::{CdObjective, HuberProblem, Loss, SqHingeProblem};
use shotgun::solvers::common::{CdSolve, SolveOptions, SolveResult};
use shotgun::solvers::{
    cdn::ShootingCdn,
    glmnet::Glmnet,
    hybrid::HybridSgdShotgun,
    parallel_sgd::ParallelSgd,
    sgd::{Rate, Sgd},
    shooting::Shooting,
    smidas::Smidas,
};
use shotgun::sparsela::{DenseMatrix, Design};
use shotgun::testkit::requests::{stream, StreamSpec};
use shotgun::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

const P: usize = 4;
const ETA: f64 = 0.05;

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag}: weight {i} differs ({x} vs {y})"
        );
    }
}

/// Direct construction of every multi-loss solver, driven through the
/// generic `CdSolve` body — the statically-dispatched reference the
/// erased registry path must reproduce bit-for-bit.
fn direct_solve<O: CdObjective + Sync>(
    name: &str,
    obj: &O,
    x0: &[f64],
    o: &SolveOptions,
) -> SolveResult {
    match name {
        "shotgun" => Shotgun::new(ShotgunConfig {
            p: P,
            ..Default::default()
        })
        .solve_obj(obj, x0, o),
        "shotgun-cdn" => ShotgunCdn::with_p(P).solve_obj(obj, x0, o),
        "shooting" => Shooting.solve_obj(obj, x0, o),
        "shooting-cdn" => ShootingCdn::default().solve_obj(obj, x0, o),
        "sgd" => Sgd::new(Rate::Constant(ETA)).solve_obj(obj, x0, o),
        "parallel-sgd" => ParallelSgd::new(P, Rate::Constant(ETA)).solve_obj(obj, x0, o),
        "smidas" => Smidas::new(ETA.min(0.1)).solve_obj(obj, x0, o),
        "hybrid" => HybridSgdShotgun {
            eta: ETA,
            p: P,
            ..Default::default()
        }
        .solve_obj(obj, x0, o),
        "glmnet" => Glmnet::default().solve_obj(obj, x0, o),
        other => panic!("no direct reference for {other} — extend this table"),
    }
}

fn opts_for(unit: shotgun::api::IterUnit) -> SolveOptions {
    let max_iters = match unit {
        shotgun::api::IterUnit::Update | shotgun::api::IterUnit::Round => 60_000,
        shotgun::api::IterUnit::Sweep => 1_500,
        shotgun::api::IterUnit::Epoch => 40,
    };
    SolveOptions {
        max_iters,
        tol: 1e-7,
        record_every: 512,
        seed: 9,
        ..Default::default()
    }
}

fn run_bit_identity(loss: Loss) {
    let ds = if loss.classifies() {
        synth::rcv1_like(50, 40, 0.2, 41)
    } else {
        synth::sparse_imaging(50, 60, 0.1, 42)
    };
    let lam = 0.08;
    let d = ds.d();
    let x0 = vec![0.0; d];
    let params = SolverParams {
        p: P,
        eta: ETA,
        ..Default::default()
    };
    for entry in SolverRegistry::global()
        .entries()
        .iter()
        .filter(|e| e.caps.supports(loss) && e.caps.deterministic)
    {
        let sqhinge;
        let huber;
        let o = opts_for(entry.caps.iter_unit);
        let (direct, prob): (SolveResult, ProblemRef<'_, '_>) = match loss {
            Loss::SqHinge => {
                sqhinge = SqHingeProblem::new(&ds.design, &ds.targets, lam);
                (
                    direct_solve(entry.name, &sqhinge, &x0, &o),
                    ProblemRef::SqHinge(&sqhinge),
                )
            }
            Loss::Huber => {
                huber = HuberProblem::new(&ds.design, &ds.targets, lam);
                (
                    direct_solve(entry.name, &huber, &x0, &o),
                    ProblemRef::Huber(&huber),
                )
            }
            other => panic!("not a beyond-paper loss: {other:?}"),
        };
        // route 1: the erased registry handle
        let erased = entry
            .create(&params)
            .solve(prob, &x0, &o)
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_bits_eq(&erased.x, &direct.x, entry.name);
        assert_eq!(
            erased.objective.to_bits(),
            direct.objective.to_bits(),
            "{}: objective bits differ",
            entry.name
        );
        // route 2: the Fit front door
        let report = Fit::new(&ds.design, &ds.targets)
            .loss(loss)
            .lambda(lam)
            .solver(entry.name)
            .params(params.clone())
            .options(|opt| *opt = o.clone())
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_bits_eq(&report.diagnostics.x, &direct.x, entry.name);
        assert_bits_eq(&report.model.to_dense(), &direct.x, entry.name);
        assert_eq!(report.model.loss, loss);
        // the identity must come from real work, not a shared no-op
        assert!(direct.updates > 0, "{}: reference did no work", entry.name);
    }
}

#[test]
fn registry_and_fit_match_direct_solve_cd_bit_for_bit_sqhinge() {
    run_bit_identity(Loss::SqHinge);
}

#[test]
fn registry_and_fit_match_direct_solve_cd_bit_for_bit_huber() {
    run_bit_identity(Loss::Huber);
}

// ---------------------------------------------------------------------
// fixture optimum through Engine::Auto and the pathwise orchestrator
// ---------------------------------------------------------------------

struct Fixture {
    loss: Loss,
    design: Design,
    targets: Vec<f64>,
    lam: f64,
    f_star: f64,
}

fn load_fixture(file: &str) -> Fixture {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let doc = Json::parse(&text).expect("fixture is valid JSON");
    let num_vec = |key: &str| -> Vec<f64> {
        doc.get(key)
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("{file}: missing array {key}"))
            .iter()
            .map(|v| v.as_f64().expect("numeric array"))
            .collect()
    };
    let n = doc.get("n").and_then(Json::as_usize).expect("n");
    let d = doc.get("d").and_then(Json::as_usize).expect("d");
    Fixture {
        loss: doc
            .get("loss")
            .and_then(Json::as_str)
            .and_then(Loss::parse)
            .expect("fixture loss tag"),
        design: Design::Dense(DenseMatrix::from_col_major(n, d, num_vec("col_major"))),
        targets: num_vec("targets"),
        lam: doc.get("lam").and_then(Json::as_f64).expect("lam"),
        f_star: doc.get("f_star").and_then(Json::as_f64).expect("f_star"),
    }
}

#[test]
fn engine_auto_and_pathwise_reach_the_numpy_optimum_on_new_losses() {
    for file in [
        "sqhinge_small.json",
        "sqhinge_wide.json",
        "huber_small.json",
        "huber_wide.json",
    ] {
        let fx = load_fixture(file);
        // Engine::Auto (Theorem 3.2 picks P + the engine)
        let auto = Fit::new(&fx.design, &fx.targets)
            .loss(fx.loss)
            .lambda(fx.lam)
            .engine(Engine::Auto)
            .options(|o| {
                o.max_iters = 500_000;
                o.tol = 1e-10;
            })
            .run()
            .unwrap_or_else(|e| panic!("{file}: auto fit failed: {e}"));
        let gap = (auto.objective() - fx.f_star) / fx.f_star.max(1.0);
        assert!(
            (-1e-8..=1e-4).contains(&gap),
            "{file}: Engine::Auto landed at {} vs fixture {} (rel gap {gap:.2e})",
            auto.objective(),
            fx.f_star
        );
        // pathwise strong-rules orchestrator down to the fixture lambda
        let path = Fit::new(&fx.design, &fx.targets)
            .loss(fx.loss)
            .path(PathSpec::to(fx.lam))
            .solver("shooting")
            .options(|o| {
                o.max_iters = 500_000;
                o.tol = 1e-10;
            })
            .run()
            .unwrap_or_else(|e| panic!("{file}: pathwise fit failed: {e}"));
        let gap = (path.objective() - fx.f_star) / fx.f_star.max(1.0);
        assert!(
            (-1e-8..=1e-4).contains(&gap),
            "{file}: pathwise landed at {} vs fixture {} (rel gap {gap:.2e})",
            path.objective(),
            fx.f_star
        );
        assert!(
            path.diagnostics.solver.contains("+path"),
            "{file}: pathwise tag missing: {}",
            path.diagnostics.solver
        );
    }
}

#[test]
fn strong_rules_engage_and_preserve_the_optimum_on_new_losses() {
    // sparse instances large enough for the screen to drop coordinates:
    // the solver tag must gain "+path-strong" and the objective must
    // match the strong-rules-off path
    for loss in [Loss::SqHinge, Loss::Huber] {
        let ds = if loss.classifies() {
            synth::rcv1_like(80, 160, 0.06, 43)
        } else {
            synth::sparse_imaging(80, 160, 0.06, 44)
        };
        let lam_frac = 0.15;
        let (lam, run) = {
            let lam = match loss {
                Loss::SqHinge => {
                    lam_frac * SqHingeProblem::new(&ds.design, &ds.targets, 0.0).lambda_max()
                }
                _ => lam_frac * HuberProblem::new(&ds.design, &ds.targets, 0.0).lambda_max(),
            };
            let run = |strong: bool| {
                Fit::new(&ds.design, &ds.targets)
                    .loss(loss)
                    .path(PathSpec {
                        lam_target: lam,
                        stages: 6,
                        strong_rules: strong,
                    })
                    .solver("shooting")
                    .options(|o| {
                        o.max_iters = 400_000;
                        o.tol = 1e-8;
                    })
                    .run()
                    .expect("pathwise fit solves")
            };
            (lam, run)
        };
        let strong = run(true);
        let plain = run(false);
        assert!(
            strong.diagnostics.solver.ends_with("+path-strong"),
            "{loss:?}: screening never engaged at lam {lam}: {}",
            strong.diagnostics.solver
        );
        let gap = (strong.objective() - plain.objective()).abs()
            / plain.objective().abs().max(1e-12);
        assert!(
            gap < 1e-3,
            "{loss:?}: strong rules moved the optimum (gap {gap:.2e})"
        );
    }
}

// ---------------------------------------------------------------------
// serving: fit queue, replay, JSON round-trip, proba refusal
// ---------------------------------------------------------------------

#[test]
fn fit_queue_and_replay_serve_the_new_losses() {
    for loss in [Loss::SqHinge, Loss::Huber] {
        let ds = if loss.classifies() {
            synth::rcv1_like(60, 80, 0.15, 45)
        } else {
            synth::sparse_imaging(60, 80, 0.15, 46)
        };
        let store = Arc::new(ModelStore::new());
        let queue = FitQueue::with_store(2, 8, Arc::clone(&store)).expect("valid queue params");
        let design = Arc::new(ds.design);
        let targets = Arc::new(ds.targets);
        let job = FitJob::new(Arc::clone(&design), Arc::clone(&targets), loss, 0.05)
            .solver_name("shooting")
            .options(|o| {
                o.max_iters = 200_000;
                o.tol = 1e-7;
            })
            .publish_as("beyond");
        let id = queue.submit(job).expect("queue accepts the job");
        let report = match queue.wait(id).expect("job is known") {
            JobState::Done(report) => report,
            other => panic!("{loss:?}: job did not finish: {other:?}"),
        };
        assert_eq!(report.model.loss, loss);

        // the published artifact round-trips bit-exactly
        let record = store.resolve("beyond").expect("published");
        let restored = Model::from_json(&record.model.to_json()).expect("roundtrip");
        assert_eq!(restored, record.model);

        // replay a request stream against it (no proba: only logistic
        // models carry a probabilistic read-out)
        let spec = StreamSpec {
            d: design.d(),
            count: 200,
            max_nnz: 6,
            proba_fraction: 0.0,
        };
        let requests = stream(&spec, 2127);
        let stats = replay(
            Arc::clone(&store),
            "beyond",
            &requests,
            &ReplayConfig::default(),
        )
        .expect("replay serves the stream");
        assert_eq!(stats.requests, 200);

        // a proba request against a non-logistic model is refused
        let mut bad = requests[0].clone();
        bad.proba = true;
        let err = replay(
            Arc::clone(&store),
            "beyond",
            std::slice::from_ref(&bad),
            &ReplayConfig::default(),
        )
        .expect_err("proba must be refused");
        assert!(
            matches!(err, shotgun::api::ShotgunError::BadRequest { .. }),
            "{loss:?}: wrong refusal: {err:?}"
        );
    }
}
