//! Cross-engine invariance of the coordinate scheduler: every engine
//! (exact, threaded, CDN, plus the sequential baselines Shooting and
//! GLMNET) must reach the same objective with shrinking on vs off
//! (relative gap < 1e-3) — the full-sweep KKT recheck makes active-set
//! shrinking an optimization, never an approximation.

use shotgun::coordinator::{ShotgunCdn, ShotgunConfig, ShotgunExact, ShotgunThreaded, ShrinkConfig};
use shotgun::data::synth;
use shotgun::objective::{LassoProblem, LogisticProblem};
use shotgun::solvers::common::{LogisticSolver as _, SolveOptions};
use shotgun::solvers::glmnet::Glmnet;
use shotgun::solvers::shooting::Shooting;
use shotgun::solvers::LassoSolver as _;

fn opts_on() -> SolveOptions {
    SolveOptions {
        max_iters: 400_000,
        tol: 1e-8,
        record_every: u64::MAX,
        seed: 5,
        ..Default::default()
    }
}

fn opts_off() -> SolveOptions {
    SolveOptions {
        shrink: ShrinkConfig::disabled(),
        ..opts_on()
    }
}

fn rel_gap(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

#[test]
fn all_lasso_engines_agree_shrink_on_vs_off() {
    let ds = synth::sparse_imaging(120, 240, 0.06, 7);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.12);
    let x0 = vec![0.0; 240];

    let mut results: Vec<(String, f64, f64)> = Vec::new();
    // exact engine
    let cfg = ShotgunConfig {
        p: 4,
        ..Default::default()
    };
    results.push((
        "exact".into(),
        ShotgunExact::new(cfg.clone())
            .solve_lasso(&prob, &x0, &opts_on())
            .objective,
        ShotgunExact::new(cfg.clone())
            .solve_lasso(&prob, &x0, &opts_off())
            .objective,
    ));
    // threaded engine
    results.push((
        "threaded".into(),
        ShotgunThreaded::new(cfg.clone())
            .solve_lasso(&prob, &x0, &opts_on())
            .objective,
        ShotgunThreaded::new(cfg.clone())
            .solve_lasso(&prob, &x0, &opts_off())
            .objective,
    ));
    // sequential baselines ride the same scheduler
    results.push((
        "shooting".into(),
        Shooting.solve_lasso(&prob, &x0, &opts_on()).objective,
        Shooting.solve_lasso(&prob, &x0, &opts_off()).objective,
    ));
    results.push((
        "glmnet".into(),
        Glmnet::default().solve_lasso(&prob, &x0, &opts_on()).objective,
        Glmnet::default()
            .solve_lasso(&prob, &x0, &opts_off())
            .objective,
    ));

    let reference = results[0].2; // exact engine, shrink off
    for (name, on, off) in &results {
        assert!(
            rel_gap(*on, *off) < 1e-3,
            "{name}: shrink-on {on} vs shrink-off {off}"
        );
        assert!(
            rel_gap(*on, reference) < 1e-3,
            "{name} disagrees with the exact engine: {on} vs {reference}"
        );
    }
}

fn logistic_opts(shrink_on: bool) -> SolveOptions {
    // fixed-step logistic CD contracts slowly near the optimum; a 1e-7
    // window keeps these tests fast while the 1e-3 gap is what matters
    SolveOptions {
        tol: 1e-7,
        shrink: if shrink_on {
            ShrinkConfig::default()
        } else {
            ShrinkConfig::disabled()
        },
        ..opts_on()
    }
}

#[test]
fn cdn_agrees_shrink_on_vs_off() {
    let ds = synth::rcv1_like(80, 60, 0.2, 3);
    let prob = LogisticProblem::new(&ds.design, &ds.targets, 0.05);
    let x0 = vec![0.0; 60];
    let on = ShotgunCdn::with_p(4)
        .solve_logistic(&prob, &x0, &logistic_opts(true))
        .objective;
    let off = ShotgunCdn::with_p(4)
        .solve_logistic(&prob, &x0, &logistic_opts(false))
        .objective;
    assert!(rel_gap(on, off) < 1e-3, "cdn: on {on} vs off {off}");
}

#[test]
fn logistic_exact_agrees_shrink_on_vs_off() {
    let ds = synth::rcv1_like(60, 40, 0.25, 6);
    let prob = LogisticProblem::new(&ds.design, &ds.targets, 0.05);
    let x0 = vec![0.0; 40];
    let mk = || {
        ShotgunExact::new(ShotgunConfig {
            p: 4,
            ..Default::default()
        })
    };
    let on = mk().solve_logistic(&prob, &x0, &logistic_opts(true)).objective;
    let off = mk()
        .solve_logistic(&prob, &x0, &logistic_opts(false))
        .objective;
    assert!(rel_gap(on, off) < 1e-3, "logistic: on {on} vs off {off}");
    let shooting_on = Shooting
        .solve_logistic(&prob, &x0, &logistic_opts(true))
        .objective;
    assert!(rel_gap(shooting_on, off) < 1e-3);
}
