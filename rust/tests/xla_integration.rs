//! Integration tests over the full three-layer path: JAX/Pallas AOT
//! artifacts (built by `make artifacts`) loaded and executed through the
//! PJRT CPU client, cross-checked against the pure-rust engines.
//!
//! Gated behind the `xla` cargo feature: the default build ships only
//! the stub runtime (see `rust/Cargo.toml`), so a default
//! `cargo test -q` never opens the engine at all — no stub probing, no
//! artifacts/ scan. `cargo check --features xla --all-targets` (the CI
//! xla-check job) compiles these tests against the stub surface so they
//! cannot bit-rot; a real run needs `--features xla-pjrt` on a machine
//! with the external `xla` crate, and the tests still skip cleanly
//! there if `make artifacts` has not been run.
#![cfg(feature = "xla")]

use shotgun::coordinator::{Engine, ShotgunConfig, ShotgunExact};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::runtime::XlaLassoEngine;
use shotgun::solvers::common::SolveOptions;
use shotgun::sparsela::power;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("artifacts/ not built; skipping XLA integration test");
        None
    }
}

#[test]
fn xla_engine_solves_dense_lasso() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaLassoEngine::open(dir, "s").expect("open engine");
    let (big_n, big_d, _, _) = engine.profile_shape();
    assert!(big_n >= 128 && big_d >= 128);

    let ds = synth::singlepix_pm1(128, 128, 42);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.3);
    let opts = SolveOptions {
        max_iters: 6_000,
        tol: 1e-5,
        seed: 7,
        ..Default::default()
    };
    let res = engine
        .solve_lasso(&prob, &vec![0.0; 128], &opts)
        .expect("xla solve");
    // compare against the exact rust engine at the same P
    let cfg = ShotgunConfig {
        p: 8,
        engine: Engine::Exact,
        ..Default::default()
    };
    let rust_res = ShotgunExact::new(cfg).solve_lasso(
        &prob,
        &vec![0.0; 128],
        &SolveOptions {
            max_iters: 200_000,
            tol: 1e-8,
            seed: 7,
            ..Default::default()
        },
    );
    let f0 = prob.objective(&vec![0.0; 128]);
    assert!(
        res.objective < 0.9 * f0,
        "xla engine failed to descend: {} vs F0 {}",
        res.objective,
        f0
    );
    // f32 device path tracks the f64 rust optimum to float precision
    let rel = (res.objective - rust_res.objective).abs() / rust_res.objective;
    assert!(
        rel < 5e-2,
        "xla {} vs rust {} (rel {rel})",
        res.objective,
        rust_res.objective
    );
}

#[test]
fn xla_power_iteration_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaLassoEngine::open(dir, "s").expect("open engine");
    let ds = synth::singlepix_binary(128, 64, 3);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let rho_dev = engine.power_iter_rho(&prob).expect("device rho");
    let rho_rust = power::spectral_radius(&ds.design, 2000, 1e-10, 5).rho;
    let rel = (rho_dev - rho_rust).abs() / rho_rust;
    assert!(
        rel < 1e-2,
        "device rho {rho_dev} vs rust {rho_rust} (rel {rel})"
    );
}

#[test]
fn xla_engine_rejects_oversized_problems() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = XlaLassoEngine::open(dir, "s").expect("open engine");
    let (big_n, big_d, _, _) = engine.profile_shape();
    let ds = synth::singlepix_pm1(big_n + 1, big_d, 1);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    assert!(engine
        .solve_lasso(&prob, &vec![0.0; big_d], &SolveOptions::default())
        .is_err());
}
