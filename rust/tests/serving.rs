//! Deterministic end-to-end harness for the serving subsystem
//! (`api::serve`) — the three contracts the ISSUE names:
//!
//! 1. **Batch bit-identity**: coalesced `BatchPredictor` output is
//!    bit-identical to one-at-a-time `Model::predict` /
//!    `predict_proba`, for every batch composition.
//! 2. **Worker-count independence**: a `FitQueue` job's result depends
//!    only on its spec — 1 worker vs N workers produce bit-equal
//!    weights on deterministic solvers.
//! 3. **Hot-swap atomicity**: concurrent readers hammering a
//!    `ModelStore` during publishes only ever see complete records —
//!    version and weights always belong to the same publish.
//!
//! Everything is seeded (`testkit::requests`), so a failure replays
//! exactly.

use shotgun::api::serve::{
    batch_design, BatchConfig, BatchPredictor, BatchServer, FitJob, FitQueue, FlushFairness,
    JobState, ModelStore,
};
use shotgun::api::{Fit, Model};
use shotgun::data::synth;
use shotgun::objective::Loss;
use shotgun::simserve::Clock;
use shotgun::sparsela::Design;
use shotgun::testkit;
use shotgun::testkit::requests::{stream, StreamSpec};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag}: bit mismatch at [{i}]: {x} vs {y}"
        );
    }
}

/// A real fitted model (not a synthetic weight vector) so the serving
/// path is exercised against solver output.
fn fitted_model(loss: Loss, seed: u64) -> Model {
    // classification losses need ±1 labels; regression losses real
    // targets — every loss goes through the same serving contract
    let ds = if loss.classifies() {
        synth::rcv1_like(60, 120, 0.1, seed)
    } else {
        synth::sparse_imaging(60, 120, 0.1, seed)
    };
    Fit::new(&ds.design, &ds.targets)
        .loss(loss)
        .lambda(0.05)
        .solver(if loss.classifies() {
            "shooting-cdn"
        } else {
            "shooting"
        })
        .options(|o| {
            o.max_iters = 200_000;
            o.tol = 1e-7;
        })
        .run()
        .expect("small fit converges")
        .model
}

// ---------------------------------------------------------------------
// contract 1: batched prediction is bit-identical to sequential
// ---------------------------------------------------------------------

#[test]
fn batched_prediction_is_bit_identical_to_sequential() {
    // all four losses, including the beyond-paper pair: the coalesced
    // path must be bit-identical whatever the predict semantics are
    for loss in Loss::ALL {
        let model = fitted_model(loss, 11);
        let d = model.d();
        let store = Arc::new(ModelStore::new());
        store.publish("m", model.clone());

        let spec = StreamSpec {
            d,
            count: 300,
            max_nnz: 10,
            proba_fraction: if loss == Loss::Logistic { 0.4 } else { 0.0 },
        };
        let requests = stream(&spec, 2027);

        // sequential baseline: one-at-a-time Model::predict through the
        // same canonical request embedding
        let mut seq_pred = Vec::with_capacity(requests.len());
        let mut seq_proba = Vec::with_capacity(requests.len());
        for req in &requests {
            let single: Design = batch_design(std::slice::from_ref(req), d).unwrap();
            seq_pred.push(model.predict(&single).unwrap()[0]);
            seq_proba.push(if req.proba {
                Some(model.predict_proba(&single).unwrap()[0])
            } else {
                None
            });
        }

        // batched, across very different batch compositions
        for max_batch in [1usize, 7, 64, 300] {
            let mut bp = BatchPredictor::new(
                Arc::clone(&store),
                "m",
                BatchConfig {
                    max_batch,
                    ..Default::default()
                },
            );
            let out = bp.run(&requests).expect("well-formed stream");
            assert_eq!(out.len(), requests.len());
            let got_pred: Vec<f64> = out.iter().map(|r| r.prediction).collect();
            assert_bits_eq(
                &got_pred,
                &seq_pred,
                &format!("{loss:?} predictions, max_batch={max_batch}"),
            );
            for (i, (resp, want)) in out.iter().zip(&seq_proba).enumerate() {
                match (resp.proba, want) {
                    (Some(got), Some(want)) => assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "proba mismatch at [{i}], max_batch={max_batch}"
                    ),
                    (None, None) => {}
                    other => panic!("proba presence mismatch at [{i}]: {other:?}"),
                }
            }
        }
    }
}

#[test]
fn batch_server_matches_the_synchronous_front_on_virtual_time() {
    // the threaded collector changes WHEN batches flush, never WHAT
    // they contain — outputs must match the synchronous front exactly.
    // The collector runs on a SimClock, so the 300us max_wait flush
    // fires exactly when the driver advances past it — the test asserts
    // flush *timing*, not just values, and can never flake on a slow
    // host the way a wall-clock 300us window could.
    let model = fitted_model(Loss::Squared, 12);
    let d = model.d();
    let store = Arc::new(ModelStore::new());
    store.publish("m", model);
    let requests = stream(&StreamSpec::new(d, 200), 5);

    let mut sync_front = BatchPredictor::new(Arc::clone(&store), "m", BatchConfig::default());
    let expect = sync_front.run(&requests).unwrap();

    let clock = Clock::sim();
    let sim = Arc::clone(clock.sim_handle().unwrap());
    let mut server = BatchServer::spawn_with_clock(
        Arc::clone(&store),
        "m",
        BatchConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(300),
            ..Default::default()
        },
        clock,
    );
    let tickets: Vec<_> = requests.iter().map(|r| server.submit(r.clone())).collect();
    sim.until_quiescent();
    // all 200 requests landed at virtual tick 0: twelve full batches of
    // 16 flush immediately, the last 8 sit on the max_wait timer
    let mut got: Vec<_> = tickets
        .iter()
        .map(|t| t.poll().map(|r| r.expect("served")))
        .collect();
    assert!(got[..192].iter().all(Option::is_some), "full batches flush at once");
    assert!(
        got[192..].iter().all(Option::is_none),
        "the partial batch must wait for the virtual max_wait deadline"
    );
    assert_eq!(
        sim.next_deadline(),
        Some(300_000),
        "flush deadline = first pending arrival (tick 0) + 300us"
    );
    sim.advance_to(300_000);
    sim.until_quiescent();
    for (ticket, slot) in tickets.iter().zip(&mut got) {
        if slot.is_none() {
            *slot = Some(ticket.poll().expect("flushed at the deadline").expect("served"));
        }
    }
    assert_eq!(server.counters().batches.load(Ordering::Relaxed), 13);
    for (got, want) in got.iter().zip(&expect) {
        let got = got.as_ref().expect("every ticket served");
        assert_eq!(got.prediction.to_bits(), want.prediction.to_bits());
        assert_eq!(got.score.to_bits(), want.score.to_bits());
    }
    server.shutdown();
}

#[test]
fn resolved_tickets_free_their_admission_slots_at_resolve_time() {
    // regression: the in-flight gate used to decrement only when a
    // ticket was DROPPED, so a client that kept resolved tickets alive
    // (to read responses later) eventually wedged admission shut. The
    // slot must free when the response is delivered, not when the
    // ticket goes away.
    let model = fitted_model(Loss::Squared, 13);
    let d = model.d();
    let store = Arc::new(ModelStore::new());
    store.publish("m", model);
    let clock = Clock::sim();
    let sim = Arc::clone(clock.sim_handle().unwrap());
    let mut server = BatchServer::spawn_with_clock(
        Arc::clone(&store),
        "m",
        BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            max_in_flight: 4,
            ..Default::default()
        },
        clock,
    );
    let requests = stream(&StreamSpec::new(d, 12), 17);
    let mut kept = Vec::new(); // resolved tickets deliberately kept alive
    for (k, chunk) in requests.chunks(4).enumerate() {
        let tickets: Vec<_> = chunk.iter().map(|r| server.submit(r.clone())).collect();
        sim.until_quiescent(); // backlog == max_batch: flushes at once
        for (i, t) in tickets.iter().enumerate() {
            assert!(
                t.poll().expect("full batch flushed").is_ok(),
                "chunk {k} ticket {i}: shed although the previous chunk resolved"
            );
        }
        kept.extend(tickets);
    }
    assert_eq!(
        server.counters().shed.load(Ordering::Relaxed),
        0,
        "resolved-but-alive tickets must not occupy admission slots"
    );
    drop(kept);
    server.shutdown();
}

// ---------------------------------------------------------------------
// contract 2: FitQueue results are independent of worker count
// ---------------------------------------------------------------------

fn queue_jobs(design: &Arc<Design>, targets: &Arc<Vec<f64>>) -> Vec<FitJob> {
    // deterministic solvers only (the threaded engine is documented as
    // non-deterministic in the registry capabilities)
    let mut jobs = Vec::new();
    for (solver, lam) in [
        ("shooting", 0.3),
        ("shooting", 0.15),
        ("shotgun", 0.3),
        ("shotgun-cdn", 0.2),
        ("glmnet", 0.25),
    ] {
        jobs.push(
            FitJob::new(
                Arc::clone(design),
                Arc::clone(targets),
                Loss::Squared,
                lam,
            )
            .solver_name(solver)
            .options(|o| {
                o.max_iters = 120_000;
                o.tol = 1e-7;
                o.seed = 33;
            }),
        );
    }
    jobs
}

#[test]
fn fit_queue_results_are_independent_of_worker_count() {
    let ds = synth::sparse_imaging(50, 90, 0.1, 21);
    let design = Arc::new(ds.design);
    let targets = Arc::new(ds.targets);

    let solve_all = |workers: usize| -> Vec<Vec<f64>> {
        let queue = FitQueue::new(workers, 16).expect("valid queue params");
        let ids: Vec<_> = queue_jobs(&design, &targets)
            .into_iter()
            .map(|j| queue.submit(j).expect("queue open"))
            .collect();
        // one design across all jobs -> exactly one shared cache entry
        let results = ids
            .into_iter()
            .map(|id| match queue.wait(id).expect("known id") {
                JobState::Done(report) => report.diagnostics.x.clone(),
                other => panic!("job ended as {other:?}"),
            })
            .collect();
        assert_eq!(queue.cache_hub().len(), 1);
        results
    };

    let single = solve_all(1);
    for workers in [2, 4] {
        let multi = solve_all(workers);
        for (i, (a, b)) in single.iter().zip(&multi).enumerate() {
            assert_bits_eq(a, b, &format!("job {i}, {workers} workers vs 1"));
        }
    }
}

// ---------------------------------------------------------------------
// contract 3: hot-swap never serves a torn model
// ---------------------------------------------------------------------

#[test]
fn hot_swap_never_serves_a_torn_model() {
    // two distinguishable models: even versions carry weights_b, odd
    // versions weights_a; a torn read would pair a version with the
    // other publish's weights (or non-constant weights)
    let d = 32;
    let weights_a: Vec<f64> = (0..d).map(|j| 1.0 + j as f64).collect();
    let weights_b: Vec<f64> = (0..d).map(|j| -(2.0 + j as f64)).collect();
    let store = Arc::new(ModelStore::new());
    store.publish("m", Model::from_dense(&weights_a, Loss::Squared, 0.1, "a"));

    let probe = stream(&StreamSpec::new(d, 8), 99);
    let record = store.get("m").unwrap();
    let expect_a = shotgun::api::serve::predict_coalesced(&record, &probe).unwrap();
    store.publish("m", Model::from_dense(&weights_b, Loss::Squared, 0.1, "b"));
    let record = store.get("m").unwrap();
    let expect_b = shotgun::api::serve::predict_coalesced(&record, &probe).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    const SWAPS: u64 = 400;

    std::thread::scope(|scope| {
        // writer: hot-swap a/b a few hundred times
        {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            let (wa, wb) = (weights_a.clone(), weights_b.clone());
            scope.spawn(move || {
                for k in 0..SWAPS {
                    if k % 2 == 0 {
                        store.publish("m", Model::from_dense(&wa, Loss::Squared, 0.1, "a"));
                    } else {
                        store.publish("m", Model::from_dense(&wb, Loss::Squared, 0.1, "b"));
                    }
                }
                stop.store(true, Ordering::Release);
            });
        }
        // readers: every observed record must be internally consistent
        for _ in 0..3 {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            let probe = probe.clone();
            let expect_a = expect_a.clone();
            let expect_b = expect_b.clone();
            scope.spawn(move || {
                let mut seen = 0u64;
                while !stop.load(Ordering::Acquire) || seen == 0 {
                    let rec = store.get("m").expect("name never disappears");
                    // (version parity) <-> (solver tag) <-> (weights):
                    // initial publish is v1 = "a", so odd versions are
                    // always "a", even always "b"
                    let expect_tag = if rec.version % 2 == 1 { "a" } else { "b" };
                    assert_eq!(
                        rec.model.solver, expect_tag,
                        "torn record: version {} paired with solver {:?}",
                        rec.version, rec.model.solver
                    );
                    let out =
                        shotgun::api::serve::predict_coalesced(&rec, &probe).expect("probe");
                    let want = if expect_tag == "a" { &expect_a } else { &expect_b };
                    for (got, want) in out.iter().zip(want) {
                        assert_eq!(
                            got.score.to_bits(),
                            want.score.to_bits(),
                            "torn record: weights do not match version {}",
                            rec.version
                        );
                    }
                    seen += 1;
                }
                assert!(seen > 0);
            });
        }
    });

    // after the dust settles: 2 setup publishes + SWAPS from the writer
    let final_rec = store.get("m").unwrap();
    assert_eq!(final_rec.version, SWAPS + 2);
}

// ---------------------------------------------------------------------
// composition: queue -> store -> batch, with a mid-stream hot swap
// ---------------------------------------------------------------------

#[test]
fn queue_store_batch_compose_end_to_end() {
    let ds = synth::sparse_imaging(50, 90, 0.1, 77);
    let design = Arc::new(ds.design);
    let targets = Arc::new(ds.targets);
    let store = Arc::new(ModelStore::new());
    let queue = FitQueue::with_store(2, 8, Arc::clone(&store)).expect("valid queue params");

    // fit v1, serve, refit at a different lambda (hot-swap), serve again
    let submit = |lam: f64| {
        queue
            .submit(
                FitJob::new(
                    Arc::clone(&design),
                    Arc::clone(&targets),
                    Loss::Squared,
                    lam,
                )
                .solver_name("shooting")
                .options(|o| {
                    o.max_iters = 120_000;
                    o.tol = 1e-7;
                })
                .publish_as("prod"),
            )
            .expect("queue open")
    };
    let id1 = submit(0.4);
    assert!(matches!(
        queue.wait(id1).expect("known"),
        JobState::Done(_)
    ));
    let v1 = store.get("prod").unwrap();
    assert_eq!(v1.version, 1);

    let requests = stream(&StreamSpec::new(90, 64), 3);
    let mut bp = BatchPredictor::new(Arc::clone(&store), "prod", BatchConfig::default());
    let before = bp.run(&requests).unwrap();
    assert!(before.iter().all(|r| r.model_version == 1));

    let id2 = submit(0.1);
    assert!(matches!(
        queue.wait(id2).expect("known"),
        JobState::Done(_)
    ));
    let after = bp.run(&requests).unwrap();
    assert!(after.iter().all(|r| r.model_version == 2));
    // the refit at a smaller lambda actually changed the served model
    let changed = before
        .iter()
        .zip(&after)
        .any(|(a, b)| a.score.to_bits() != b.score.to_bits());
    assert!(changed, "hot-swap should change predictions");
}

// ---------------------------------------------------------------------
// multi-tenant: one router collector, many names, sharded store
// ---------------------------------------------------------------------

#[test]
fn routed_multi_model_batches_are_bit_identical_to_sequential() {
    // three distinct fitted models behind ONE router collector; requests
    // interleave names, so every flush carries mixed-name groups. Each
    // response must be bit-identical to a one-at-a-time predict on ITS
    // model, whatever the batch composition was.
    let models: Vec<Model> = [11u64, 22, 33]
        .iter()
        .map(|&seed| fitted_model(Loss::Squared, seed))
        .collect();
    let d = models[0].d();
    let store = Arc::new(ModelStore::with_shards(4));
    for (i, m) in models.iter().enumerate() {
        store.publish(&format!("m{i}"), m.clone());
    }
    let requests = stream(&StreamSpec::new(d, 120), 7);

    for max_batch in [1usize, 5, 32] {
        let clock = Clock::sim();
        let sim = Arc::clone(clock.sim_handle().unwrap());
        let mut server = BatchServer::spawn_router_with_clock(
            Arc::clone(&store),
            BatchConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
                ..Default::default()
            },
            clock,
        );
        let submitter = server.submitter();
        let tickets: Vec<_> = requests
            .iter()
            .enumerate()
            .map(|(i, r)| submitter.submit_to(&format!("m{}", i % 3), r.clone()))
            .collect();
        // drive virtual time until every pending flush (including the
        // final partial batch on the max_wait timer) has fired
        sim.until_quiescent();
        while let Some(t) = sim.next_deadline() {
            sim.advance_to(t);
            sim.until_quiescent();
        }
        for (i, ticket) in tickets.iter().enumerate() {
            let resp = ticket
                .poll()
                .unwrap_or_else(|| panic!("ticket {i} still pending, max_batch={max_batch}"))
                .expect("served");
            let model = &models[i % 3];
            let single = batch_design(std::slice::from_ref(&requests[i]), d).unwrap();
            assert_eq!(
                resp.score.to_bits(),
                model.decision_function(&single).unwrap()[0].to_bits(),
                "routed score diverged at [{i}], max_batch={max_batch}"
            );
            assert_eq!(
                resp.prediction.to_bits(),
                model.predict(&single).unwrap()[0].to_bits(),
                "routed prediction diverged at [{i}], max_batch={max_batch}"
            );
        }
        drop(tickets);
        drop(submitter);
        server.shutdown();
    }
}

#[test]
fn deficit_round_robin_flush_partitioning_follows_the_quantum_law() {
    // three fitted tenants behind one router collector; cases randomize
    // per-model backlogs, the arrival interleaving, and the DRR
    // quantum. The laws under test:
    //  * FirstSeen flushes are exactly the global arrival order;
    //  * DeficitRr with max_batch = 3*quantum gives every pending model
    //    at least min(quantum, pending) rows per flush, so a model with
    //    p backlogged rows drains within ceil(p/quantum) flushes — for
    //    ANY interleaving — and rows never reorder within a model;
    //  * under BOTH policies every response stays bit-identical to a
    //    one-at-a-time predict on its own model.
    let models: Vec<Model> = [101u64, 202, 303]
        .iter()
        .map(|&seed| fitted_model(Loss::Squared, seed))
        .collect();
    let d = models[0].d();
    let store = Arc::new(ModelStore::with_shards(4));
    for (i, m) in models.iter().enumerate() {
        store.publish(&format!("m{i}"), m.clone());
    }

    testkit::check(
        "serving-drr-quantum-law",
        0xD22,
        12,
        |rng| {
            let quantum = 1 + rng.below(3);
            let counts = [1 + rng.below(9), 1 + rng.below(9), 1 + rng.below(9)];
            let mut order: Vec<usize> = (0..3)
                .flat_map(|m| std::iter::repeat(m).take(counts[m]))
                .collect();
            // Fisher–Yates over the arrival interleaving
            for i in (1..order.len()).rev() {
                let j = rng.below(i + 1);
                order.swap(i, j);
            }
            (quantum, counts, order, rng.below(1 << 30) as u64)
        },
        |(quantum, counts, order, seed)| {
            let requests = stream(&StreamSpec::new(d, order.len()), *seed);
            for fairness in [
                FlushFairness::FirstSeen,
                FlushFairness::DeficitRr { quantum: *quantum },
            ] {
                let clock = Clock::sim();
                let sim = Arc::clone(clock.sim_handle().unwrap());
                let mut server = BatchServer::spawn_router_with_clock(
                    Arc::clone(&store),
                    BatchConfig {
                        max_batch: 3 * quantum,
                        // all rows land at tick 0, so the timer deadline
                        // is long past at every wake: each wake flushes
                        max_wait: Duration::from_micros(1),
                        fairness,
                        // a non-zero flush cost separates consecutive
                        // flushes in virtual time, making each flush's
                        // composition observable from ticket resolution
                        flush_cost: Duration::from_micros(1_000),
                        ..Default::default()
                    },
                    clock,
                );
                let submitter = server.submitter();
                let tickets: Vec<_> = order
                    .iter()
                    .zip(&requests)
                    .map(|(m, r)| submitter.submit_to(&format!("m{m}"), r.clone()))
                    .collect();
                let mut resolved = vec![false; tickets.len()];
                let drain = |resolved: &mut Vec<bool>| -> Result<Vec<usize>, String> {
                    let mut new_rows = Vec::new();
                    for (i, t) in tickets.iter().enumerate() {
                        if resolved[i] {
                            continue;
                        }
                        let Some(out) = t.poll() else { continue };
                        let resp = out.map_err(|e| format!("row {i} failed: {e:?}"))?;
                        let model = &models[order[i]];
                        let single =
                            batch_design(std::slice::from_ref(&requests[i]), d).unwrap();
                        let want = model.predict(&single).unwrap()[0];
                        if resp.prediction.to_bits() != want.to_bits() {
                            return Err(format!(
                                "{fairness:?}: row {i} prediction diverged from its model"
                            ));
                        }
                        let want = model.decision_function(&single).unwrap()[0];
                        if resp.score.to_bits() != want.to_bits() {
                            return Err(format!(
                                "{fairness:?}: row {i} score diverged from its model"
                            ));
                        }
                        resolved[i] = true;
                        new_rows.push(i);
                    }
                    Ok(new_rows)
                };
                // each deadline wake dispatches at most one flush (the
                // flush-cost sleep separates them), so the newly
                // resolved tickets after a wake ARE that flush's rows
                let mut flushes: Vec<Vec<usize>> = Vec::new();
                sim.until_quiescent();
                let rows = drain(&mut resolved)?;
                if !rows.is_empty() {
                    flushes.push(rows);
                }
                while let Some(t) = sim.next_deadline() {
                    sim.advance_to(t);
                    sim.until_quiescent();
                    let rows = drain(&mut resolved)?;
                    if !rows.is_empty() {
                        flushes.push(rows);
                    }
                }
                if !resolved.iter().all(|&r| r) {
                    return Err(format!("{fairness:?}: rows left unserved"));
                }
                let flat: Vec<usize> = flushes.concat();
                match fairness {
                    FlushFairness::FirstSeen => {
                        // global FIFO: flushes are arrival-order slices
                        if flat != (0..order.len()).collect::<Vec<_>>() {
                            return Err(format!(
                                "FirstSeen must drain in arrival order, got {flat:?}"
                            ));
                        }
                    }
                    FlushFairness::DeficitRr { quantum } => {
                        for m in 0..3 {
                            // drained within ceil(p/quantum) flushes
                            let bound = counts[m].div_ceil(quantum);
                            let early: usize = flushes
                                .iter()
                                .take(bound)
                                .map(|f| f.iter().filter(|&&i| order[i] == m).count())
                                .sum();
                            if early != counts[m] {
                                return Err(format!(
                                    "model {m}: {early}/{} rows in the first {bound} \
                                     flushes (quantum {quantum}, order {order:?})",
                                    counts[m]
                                ));
                            }
                            // FIFO within the model: arrival indices of
                            // m never decrease across the flush sequence
                            let seq: Vec<usize> =
                                flat.iter().copied().filter(|&i| order[i] == m).collect();
                            if seq.windows(2).any(|w| w[0] > w[1]) {
                                return Err(format!(
                                    "model {m}: rows reordered within the model: {seq:?}"
                                ));
                            }
                        }
                    }
                }
                drop(tickets);
                drop(submitter);
                server.shutdown();
            }
            Ok(())
        },
    );
}

#[test]
fn swaps_on_one_shard_leave_other_shards_untouched_under_load() {
    // a hot-swap storm on one name must not stall or perturb a name on
    // a DIFFERENT shard: its record Arc stays pointer-identical (the
    // other shard's write lock was never taken) and its version never
    // moves, while the swapped name itself stays torn-free
    let d = 16;
    let weights_a: Vec<f64> = (0..d).map(|j| 1.0 + j as f64).collect();
    let weights_b: Vec<f64> = (0..d).map(|j| -(2.0 + j as f64)).collect();
    let store = Arc::new(ModelStore::with_shards(4));
    store.publish(
        "stable",
        Model::from_dense(&weights_a, Loss::Squared, 0.1, "keep"),
    );
    let hot = (0..)
        .map(|k| format!("hot{k}"))
        .find(|n| store.shard_of(n) != store.shard_of("stable"))
        .expect("some name lands on another of the 4 shards");
    store.publish(&hot, Model::from_dense(&weights_a, Loss::Squared, 0.1, "a"));
    let stable_rec = store.get("stable").unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    const SWAPS: u64 = 300;
    std::thread::scope(|scope| {
        {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            let hot = hot.clone();
            let (wa, wb) = (weights_a.clone(), weights_b.clone());
            scope.spawn(move || {
                for k in 0..SWAPS {
                    // initial publish is v1 = "a": even versions are "b"
                    if k % 2 == 0 {
                        store.publish(&hot, Model::from_dense(&wb, Loss::Squared, 0.1, "b"));
                    } else {
                        store.publish(&hot, Model::from_dense(&wa, Loss::Squared, 0.1, "a"));
                    }
                }
                stop.store(true, Ordering::Release);
            });
        }
        for _ in 0..3 {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            let hot = hot.clone();
            let stable_rec = Arc::clone(&stable_rec);
            scope.spawn(move || {
                let mut seen = 0u64;
                while !stop.load(Ordering::Acquire) || seen == 0 {
                    let rec = store.get("stable").expect("name never disappears");
                    assert!(
                        Arc::ptr_eq(&rec, &stable_rec),
                        "a swap on {hot:?} replaced the record on another shard"
                    );
                    assert_eq!(rec.version, 1);
                    let h = store.get(&hot).expect("hot name present");
                    let expect_tag = if h.version % 2 == 1 { "a" } else { "b" };
                    assert_eq!(
                        h.model.solver, expect_tag,
                        "torn record on the swapped shard: version {}",
                        h.version
                    );
                    seen += 1;
                }
            });
        }
    });
    assert_eq!(store.get(&hot).unwrap().version, SWAPS + 1);
    assert_eq!(store.get("stable").unwrap().version, 1);
}
