//! API-redesign acceptance tests.
//!
//! 1. **Bit-identity**: `api::Fit` must reproduce the legacy trait path
//!    (`LassoSolver::solve_lasso` / `LogisticSolver::solve_logistic` on
//!    the concrete solver types) exactly — same seed, same options, same
//!    bits — for every deterministic registered solver, on both losses
//!    it supports. The legacy side is deliberately hand-constructed:
//!    it IS the reference being preserved.
//! 2. **Registry semantics**: enumeration covers the roster; the
//!    nondeterministic threaded engine still reaches the exact optimum.
//! 3. **Model artifact**: JSON round-trip preserves predictions
//!    bit-for-bit; serving via a shared `ProblemCache` matches
//!    uncached fits bit-for-bit.

use shotgun::api::{Fit, Model, ProblemRef, SolverParams, SolverRegistry};
use shotgun::coordinator::{Shotgun, ShotgunCdn, ShotgunConfig};
use shotgun::objective::{LassoProblem, LogisticProblem, Loss, ProblemCache};
use shotgun::solvers::common::{LassoSolver, LogisticSolver, SolveOptions, SolveResult};
use shotgun::solvers::{
    cdn::ShootingCdn,
    fpc_as::FpcAs,
    glmnet::Glmnet,
    gpsr_bb::GpsrBb,
    hard_l0::HardL0,
    hybrid::HybridSgdShotgun,
    l1_ls::L1Ls,
    parallel_sgd::ParallelSgd,
    sgd::{Rate, Sgd},
    shooting::Shooting,
    smidas::Smidas,
    sparsa::Sparsa,
};

const P: usize = 4;
const ETA: f64 = 0.05;

/// Bitwise vector equality (NaN-safe, unlike `Vec<f64> ==`).
fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag}: weight {i} differs ({x} vs {y})"
        );
    }
}

/// The pre-registry construction of every solver — the legacy reference
/// the new front door must reproduce bit-for-bit.
fn legacy_lasso(name: &str, prob: &LassoProblem, x0: &[f64], o: &SolveOptions) -> SolveResult {
    match name {
        "shotgun" => Shotgun::new(ShotgunConfig {
            p: P,
            ..Default::default()
        })
        .solve_lasso(prob, x0, o),
        "shotgun-cdn" => ShotgunCdn::with_p(P).solve_lasso(prob, x0, o),
        "shooting" => Shooting.solve_lasso(prob, x0, o),
        "shooting-cdn" => ShootingCdn::default().solve_lasso(prob, x0, o),
        "sgd" => Sgd::new(Rate::Constant(ETA)).solve_lasso(prob, x0, o),
        "parallel-sgd" => ParallelSgd::new(P, Rate::Constant(ETA)).solve_lasso(prob, x0, o),
        "smidas" => Smidas::new(ETA.min(0.1)).solve_lasso(prob, x0, o),
        "hybrid" => HybridSgdShotgun {
            eta: ETA,
            p: P,
            ..Default::default()
        }
        .solve_lasso(prob, x0, o),
        "l1-ls" => L1Ls::default().solve_lasso(prob, x0, o),
        "fpc-as" => FpcAs::default().solve_lasso(prob, x0, o),
        "gpsr-bb" => GpsrBb::default().solve_lasso(prob, x0, o),
        "sparsa" => Sparsa::default().solve_lasso(prob, x0, o),
        "hard-l0" => HardL0::with_sparsity((prob.d() / 10).max(1)).solve_lasso(prob, x0, o),
        "glmnet" => Glmnet::default().solve_lasso(prob, x0, o),
        other => panic!("no legacy reference for {other} — extend this table"),
    }
}

fn legacy_logistic(
    name: &str,
    prob: &LogisticProblem,
    x0: &[f64],
    o: &SolveOptions,
) -> SolveResult {
    match name {
        "shotgun" => Shotgun::new(ShotgunConfig {
            p: P,
            ..Default::default()
        })
        .solve_logistic(prob, x0, o),
        "shotgun-cdn" => ShotgunCdn::with_p(P).solve_logistic(prob, x0, o),
        "shooting" => Shooting.solve_logistic(prob, x0, o),
        "shooting-cdn" => ShootingCdn::default().solve_logistic(prob, x0, o),
        "sgd" => Sgd::new(Rate::Constant(ETA)).solve_logistic(prob, x0, o),
        "parallel-sgd" => ParallelSgd::new(P, Rate::Constant(ETA)).solve_logistic(prob, x0, o),
        "smidas" => Smidas::new(ETA.min(0.1)).solve_logistic(prob, x0, o),
        "hybrid" => HybridSgdShotgun {
            eta: ETA,
            p: P,
            ..Default::default()
        }
        .solve_logistic(prob, x0, o),
        "glmnet" => Glmnet::default().solve_logistic(prob, x0, o),
        other => panic!("no legacy logistic reference for {other} — extend this table"),
    }
}

fn opts_for(unit: shotgun::api::IterUnit) -> SolveOptions {
    let max_iters = match unit {
        shotgun::api::IterUnit::Update | shotgun::api::IterUnit::Round => 60_000,
        shotgun::api::IterUnit::Sweep => 1_500,
        shotgun::api::IterUnit::Epoch => 40,
    };
    SolveOptions {
        max_iters,
        tol: 1e-7,
        record_every: 512,
        seed: 9,
        ..Default::default()
    }
}

#[test]
fn fit_reproduces_legacy_lasso_bit_for_bit() {
    let ds = shotgun::data::synth::sparse_imaging(50, 60, 0.1, 31);
    let lam = 0.15;
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let x0 = vec![0.0; 60];
    let params = SolverParams {
        p: P,
        eta: ETA,
        ..Default::default()
    };
    for entry in SolverRegistry::global()
        .entries()
        .iter()
        .filter(|e| e.caps.supports(Loss::Squared) && e.caps.deterministic)
    {
        let o = opts_for(entry.caps.iter_unit);
        let legacy = legacy_lasso(entry.name, &prob, &x0, &o);
        let report = Fit::new(&ds.design, &ds.targets)
            .lambda(lam)
            .solver(entry.name)
            .params(params.clone())
            .options(|opt| *opt = o.clone())
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_bits_eq(&report.diagnostics.x, &legacy.x, entry.name);
        assert_eq!(
            report.diagnostics.objective.to_bits(),
            legacy.objective.to_bits(),
            "{}: objective bits differ",
            entry.name
        );
        assert_eq!(report.diagnostics.updates, legacy.updates, "{}", entry.name);
        // and the model artifact is the same vector, losslessly sparse
        assert_bits_eq(&report.model.to_dense(), &legacy.x, entry.name);
    }
}

#[test]
fn fit_reproduces_legacy_logistic_bit_for_bit() {
    let ds = shotgun::data::synth::rcv1_like(50, 40, 0.2, 32);
    let lam = 0.05;
    let prob = LogisticProblem::new(&ds.design, &ds.targets, lam);
    let x0 = vec![0.0; 40];
    let params = SolverParams {
        p: P,
        eta: ETA,
        ..Default::default()
    };
    for entry in SolverRegistry::global()
        .entries()
        .iter()
        .filter(|e| e.caps.supports(Loss::Logistic) && e.caps.deterministic)
    {
        let o = opts_for(entry.caps.iter_unit);
        let legacy = legacy_logistic(entry.name, &prob, &x0, &o);
        let report = Fit::new(&ds.design, &ds.targets)
            .loss(Loss::Logistic)
            .lambda(lam)
            .solver(entry.name)
            .params(params.clone())
            .options(|opt| *opt = o.clone())
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert_bits_eq(&report.diagnostics.x, &legacy.x, entry.name);
        assert_eq!(
            report.diagnostics.objective.to_bits(),
            legacy.objective.to_bits(),
            "{}: objective bits differ",
            entry.name
        );
    }
}

#[test]
fn threaded_engine_reaches_the_exact_optimum_through_fit() {
    // the one nondeterministic solver: bit-identity is not defined
    // run-to-run, but the optimum is — compare against the exact engine
    let ds = shotgun::data::synth::sparse_imaging(60, 80, 0.1, 33);
    let lam = 0.1;
    let mk = |name: &str| {
        Fit::new(&ds.design, &ds.targets)
            .lambda(lam)
            .solver(name)
            .params(SolverParams {
                p: 2,
                ..Default::default()
            })
            .options(|o| {
                o.max_iters = 500_000;
                o.tol = 1e-8;
            })
            .run()
            .expect("solves")
    };
    let exact = mk("shotgun");
    let threaded = mk("shotgun-threaded");
    let gap = (threaded.objective() - exact.objective()).abs() / exact.objective();
    assert!(gap < 1e-3, "threaded {} vs exact {}", threaded.objective(), exact.objective());
}

#[test]
fn every_registered_solver_has_a_capability_consistent_roundtrip() {
    // each entry must actually solve the losses it claims and refuse the
    // ones it does not
    let reg = SolverRegistry::global();
    let lasso_ds = shotgun::data::synth::sparco_like(30, 16, 0.4, 34);
    let lasso = LassoProblem::new(&lasso_ds.design, &lasso_ds.targets, 0.2);
    let logit_ds = shotgun::data::synth::rcv1_like(30, 16, 0.3, 35);
    let logit = LogisticProblem::new(&logit_ds.design, &logit_ds.targets, 0.05);
    let x0 = vec![0.0; 16];
    let params = SolverParams {
        p: 2,
        eta: ETA,
        ..Default::default()
    };
    for entry in reg.entries() {
        let o = opts_for(entry.caps.iter_unit);
        let mut s = entry.create(&params);
        let lasso_res = s.solve(ProblemRef::Lasso(&lasso), &x0, &o);
        assert_eq!(
            lasso_res.is_ok(),
            entry.caps.supports(Loss::Squared),
            "{}: squared capability mismatch",
            entry.name
        );
        let logit_res = s.solve(ProblemRef::Logistic(&logit), &x0, &o);
        assert_eq!(
            logit_res.is_ok(),
            entry.caps.supports(Loss::Logistic),
            "{}: logistic capability mismatch",
            entry.name
        );
    }
}

#[test]
fn model_json_roundtrip_preserves_predictions_bit_for_bit() {
    let ds = shotgun::data::synth::rcv1_like(60, 40, 0.2, 36);
    let report = Fit::new(&ds.design, &ds.targets)
        .loss(Loss::Logistic)
        .lambda(0.02)
        .solver("shotgun-cdn")
        .options(|o| o.max_iters = 50_000)
        .run()
        .unwrap();
    let model = &report.model;
    let restored = Model::from_json(&model.to_json()).expect("roundtrip");
    assert_eq!(*model, restored);
    let (a, b) = (
        model.decision_function(&ds.design).unwrap(),
        restored.decision_function(&ds.design).unwrap(),
    );
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits(), "prediction bits drifted");
    }
    assert_eq!(
        model.predict_proba(&ds.design).unwrap(),
        restored.predict_proba(&ds.design).unwrap()
    );
    // predictions beat the trivial classifier on training data
    let labels = model.predict(&ds.design).unwrap();
    let correct = labels
        .iter()
        .zip(&ds.targets)
        .filter(|(p, y)| *p == *y)
        .count();
    assert!(correct * 2 > ds.n(), "model worse than coin flip");
}

#[test]
fn serving_from_a_shared_cache_is_bit_identical() {
    // the "millions of users" pattern: one ProblemCache, many lambdas —
    // must produce exactly the fits a cold construction produces
    let ds = shotgun::data::synth::sparse_imaging(50, 100, 0.1, 37);
    let cache = ProblemCache::new(&ds.design);
    for lam in [0.5, 0.2, 0.08] {
        let served = Fit::new(&ds.design, &ds.targets)
            .lambda(lam)
            .solver("shooting")
            .cache(&cache)
            .run()
            .unwrap();
        let cold = Fit::new(&ds.design, &ds.targets)
            .lambda(lam)
            .solver("shooting")
            .run()
            .unwrap();
        assert_bits_eq(&served.diagnostics.x, &cold.diagnostics.x, "serving");
        assert_eq!(
            served.objective().to_bits(),
            cold.objective().to_bits(),
            "lam = {lam}"
        );
    }
}

#[test]
#[allow(deprecated)]
fn deprecated_facade_still_forwards() {
    // the legacy `Solver` blanket impl must keep its historical behavior
    // while it lives out its deprecation window
    use shotgun::solvers::Solver;
    let ds = shotgun::data::synth::sparco_like(40, 20, 0.3, 38);
    let legacy = Shooting.solve(&ds.design, &ds.targets, 0.2);
    let report = Fit::new(&ds.design, &ds.targets)
        .lambda(0.2)
        .solver("shooting")
        .run()
        .unwrap();
    assert_bits_eq(&legacy.x, &report.diagnostics.x, "deprecated facade");
}
