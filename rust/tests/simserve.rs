//! End-to-end harness for the `simserve` simulator — the acceptance
//! contracts the ISSUE names:
//!
//! 1. **Run-to-run determinism**: every named scenario produces an
//!    `==`-equal `Outcome` (latency percentiles included) on repeated
//!    runs.
//! 2. **Worker-count independence**: fit-queue scenarios produce the
//!    same outcome with 1, 2, or 4 workers.
//! 3. **Fault semantics**: the injected panic fails exactly its own
//!    job (the worker survives to run the recovery swap), saturation
//!    rejections are an exact function of queue capacity, a client
//!    stall deepens batches without losing requests — and batch
//!    bit-identity holds under every fault (the scenario runner checks
//!    each response; a violation panics the run).
//! 4. **QoS laws**: deficit round-robin protects the victim tenant of
//!    a flooding neighbor, EDF meets every deadline FIFO would expire,
//!    dropped tickets cost zero flush rows, and a rebalance moves heat
//!    off the hot shard — each deterministic and (where a queue is
//!    involved) worker-count independent.
//! 5. **Workload laws** (property tests over random specs): same seed →
//!    bit-identical streams, arrival counts integrate the rate curve,
//!    and the Zipf popularity tail matches its exponent.

use shotgun::simserve::report::{run_suite, suite, REQUIRED_SCENARIOS};
use shotgun::simserve::scenario::run;
use shotgun::simserve::workload::arrivals;
use shotgun::simserve::{RateCurve, Scenario, WorkloadSpec, Zipf, SECOND};
use shotgun::testkit;
use shotgun::util::json::Json;
use shotgun::util::rng::Rng;

fn named(seed: u64, name: &str) -> Scenario {
    suite(true, seed)
        .into_iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("suite has no scenario {name:?}"))
}

// ---------------------------------------------------------------------
// contract 1: run-to-run determinism of the whole named suite
// ---------------------------------------------------------------------

#[test]
fn every_named_scenario_is_run_to_run_deterministic() {
    let first = run_suite(true, 42, None).expect("suite runs");
    let second = run_suite(true, 42, None).expect("suite runs");
    // PartialEq over the WHOLE outcome struct: request counts, batch
    // composition, latency percentiles, fault counters — floats must be
    // bit-equal, not merely close
    assert_eq!(first.outcomes, second.outcomes);
    // non-vacuous: a different seed produces different traffic
    let other = run_suite(true, 43, None).expect("suite runs");
    assert_ne!(first.outcomes, other.outcomes);
}

// ---------------------------------------------------------------------
// contract 2: fit-queue scenarios are worker-count independent
// ---------------------------------------------------------------------

#[test]
fn fault_scenarios_are_worker_count_independent() {
    for name in [
        "worker-panic-recovery",
        "hot-swap-under-load",
        "multi-model-routing",
        "shard-swap-under-load",
        "overload-shedding",
        // queue-free QoS scenarios: fit_workers is inert, but the whole
        // outcome must still be identical whatever it is set to
        "flooding-tenant-firstseen",
        "flooding-tenant-fairness",
        "dropped-ticket-no-work",
        "hot-shard-rebalance",
    ] {
        let base = named(42, name);
        let outcomes: Vec<_> = [1usize, 2, 4]
            .into_iter()
            .map(|workers| {
                let mut sc = base.clone();
                sc.fit_workers = workers;
                run(&sc).expect("scenario runs")
            })
            .collect();
        assert_eq!(outcomes[0], outcomes[1], "{name}: 1 vs 2 workers");
        assert_eq!(outcomes[1], outcomes[2], "{name}: 2 vs 4 workers");
    }
}

// ---------------------------------------------------------------------
// contract 3: fault semantics
// ---------------------------------------------------------------------

#[test]
fn suite_outcomes_hold_the_declared_invariants_and_feed_the_bench_json() {
    let rep = run_suite(true, 42, None).expect("suite runs");
    let names: Vec<&str> = rep.outcomes.iter().map(|o| o.name.as_str()).collect();
    for required in REQUIRED_SCENARIOS {
        assert!(names.contains(&required), "suite must run {required}");
    }
    for o in &rep.outcomes {
        // every request either served, shed with a typed Overloaded, or
        // deliberately dropped by the driver — never failed or lost to
        // a shutdown race; every served response checked bit-for-bit
        // against sequential predict
        assert_eq!(
            o.responses + o.overloaded_responses + o.cancelled_requests,
            o.requests,
            "{}: lost requests",
            o.name
        );
        assert_eq!(o.failed_responses, 0, "{}: failed responses", o.name);
        assert_eq!(o.shutdown_responses, 0, "{}: shutdown races", o.name);
        if o.name != "overload-shedding" {
            assert_eq!(o.overloaded_responses, 0, "{}: unexpected sheds", o.name);
        }
        if o.name != "dropped-ticket-no-work" {
            assert_eq!(o.cancelled_requests, 0, "{}: unexpected drops", o.name);
            assert_eq!(o.cancelled_rows, 0, "{}: unexpected skipped rows", o.name);
        }
        assert_eq!(o.bit_identity_checked, o.responses, "{}", o.name);
        assert!(o.requests > 0 && o.batches > 0, "{}: empty run", o.name);
        assert!(
            o.p50_us <= o.p90_us && o.p90_us <= o.p99_us && o.p99_us <= o.max_us,
            "{}: percentiles out of order",
            o.name
        );
        assert!(o.virtual_seconds > 0.0 && o.throughput_rps > 0.0, "{}", o.name);
    }
    // worker panic: exactly the poisoned job fails, the worker survives
    // to complete the recovery swap, and the swap becomes visible
    let panic_recovery = rep.outcome("worker-panic-recovery").expect("ran");
    assert_eq!(panic_recovery.failed_jobs, 1);
    assert_eq!(panic_recovery.completed_jobs, 1);
    assert_eq!(panic_recovery.max_version_served, 2, "recovery swap served");
    assert!(panic_recovery.recovery_batches.expect("measured") > 0);
    // hot swap under load: finite positive visibility lag, new version
    // takes over at a batch boundary
    let swap = rep.outcome("hot-swap-under-load").expect("ran");
    let lag = swap.swap_lag_us.expect("swap observed");
    assert!(lag.is_finite() && lag > 0.0, "swap lag {lag}");
    assert_eq!(swap.max_version_served, 2);
    // saturation: 2 wedges + 2 of the 6-job burst fit the 4-slot
    // channel; the other 4 are typed rejections
    let sat = rep.outcome("queue-saturation").expect("ran");
    assert_eq!(sat.rejected_jobs, 4);
    assert_eq!(sat.completed_jobs, 4);
    assert_eq!(sat.failed_jobs, 0);
    // bursty traffic exercises the delayed (max_wait timer) flush path:
    // off-phase batches stay well under max_batch
    let bursty = rep.outcome("bursty").expect("ran");
    assert!(bursty.mean_batch < 16.0, "mean batch {}", bursty.mean_batch);
    // priority inversion: the last-submitted High job beats every Batch
    // filler, and the doomed-deadline Normals fail typed without running
    let inversion = rep.outcome("priority-inversion").expect("ran");
    assert_eq!(inversion.high_lead_jobs, 4, "High must beat all fillers");
    assert_eq!(inversion.expired_jobs, 2, "doomed jobs expire typed");
    assert_eq!(inversion.failed_jobs, 0, "expired are not failures");
    assert_eq!(inversion.rejected_jobs, 0, "capacity 16 fits the burst");
    // overload shedding: the gate sheds typed Overloaded under pressure
    // and every non-shed request still serves bit-identically
    let shedding = rep.outcome("overload-shedding").expect("ran");
    assert!(
        shedding.overloaded_responses > 0,
        "gate must shed under 8k rps with max_in_flight 8"
    );
    assert!(shedding.responses > 0, "gate must not shed everything");
    // multi-model routing: one collector served four tenants
    let routing = rep.outcome("multi-model-routing").expect("ran");
    assert_eq!(routing.responses, routing.requests);
    // shard swap: the hot swap on m0's shard stayed invisible to the
    // other tenants except as a version bump on m0 itself
    let shard_swap = rep.outcome("shard-swap-under-load").expect("ran");
    assert_eq!(shard_swap.max_version_served, 2);
    assert!(shard_swap.swap_lag_us.expect("swap observed") > 0.0);
    // flooding tenant A/B: same arrivals, and deficit round-robin must
    // cut the victim tenant's p99 vs first-seen draining
    let fs = rep.outcome("flooding-tenant-firstseen").expect("ran");
    let dr = rep.outcome("flooding-tenant-fairness").expect("ran");
    assert_eq!(fs.requests, dr.requests, "A/B pair shares its workload");
    let fs_p99 = fs.victim_p99_us.expect("victim tracked");
    let dr_p99 = dr.victim_p99_us.expect("victim tracked");
    assert!(
        fs_p99 > dr_p99,
        "DeficitRr must protect the victim: FirstSeen p99 {fs_p99} vs DRR {dr_p99}"
    );
    // EDF: every dated job of the burst completes inside its deadline
    let edf = rep.outcome("edf-beats-fifo").expect("ran");
    assert_eq!(edf.deadline_jobs, 4);
    assert_eq!(edf.deadline_met_jobs, 4, "EDF meets every deadline");
    assert_eq!(edf.expired_jobs, 0);
    // dropped tickets: exactly the dropped rows are skipped at flush
    let dropped = rep.outcome("dropped-ticket-no-work").expect("ran");
    assert_eq!(dropped.cancelled_requests, 3, "driver dropped 3 tickets");
    assert_eq!(dropped.cancelled_rows, 3, "their rows cost no flush work");
    // rebalance: heat moves off the (degenerately) hot shard
    let reb = rep.outcome("hot-shard-rebalance").expect("ran");
    assert!(reb.rebalance_moved.expect("measured") >= 1, "names re-homed");
    let before = reb.hot_share_before.expect("snapshotted");
    let after = reb.hot_share_after.expect("snapshotted");
    assert!(
        before > 0.99,
        "the fnv1a vnode ring homes every mN name on one shard: {before}"
    );
    assert!(
        after < before,
        "rebalance must spread routed reads: {before} -> {after}"
    );

    // the bench document is valid JSON with the derived fields the CI
    // gate (scripts/check_bench.py) requires to be finite and positive
    let doc = Json::parse(&rep.to_bench_json()).expect("valid JSON");
    assert_eq!(
        doc.get("bench").and_then(|b| b.as_str().map(String::from)),
        Some("simserve".into())
    );
    let derived = doc.get("derived").expect("derived section");
    for key in [
        "batching_latency_p99_ratio",
        "fault_recovery_rounds",
        "swap_visibility_lag_us",
        "overload_shed_requests",
        "priority_queue_lead_jobs",
        "fairness_p99_ratio",
        "edf_deadline_hit_rate",
        "cancelled_flush_rows",
        "rebalance_p99_gain",
        "sim_scenarios",
        "sim_requests_total",
    ] {
        let v = derived.get(key).and_then(|v| v.as_f64()).expect(key);
        assert!(v.is_finite() && v > 0.0, "{key} = {v}");
    }
}

#[test]
fn priority_inversion_laws_hold_at_any_worker_count() {
    // the High job's lead and the expired count are lane laws, not
    // timing accidents: worker 0's wedge always frees first (staggered
    // costs), pops High before any filler, and the doomed Normals are
    // long expired by then — independent of how many workers exist
    let base = named(42, "priority-inversion");
    for workers in [1usize, 2, 3] {
        let mut sc = base.clone();
        sc.fit_workers = workers;
        let out = run(&sc).expect("scenario runs");
        assert_eq!(out.high_lead_jobs, 4, "{workers} workers");
        assert_eq!(out.expired_jobs, 2, "{workers} workers");
        assert_eq!(out.failed_jobs, 0, "{workers} workers");
        assert_eq!(out.rejected_jobs, 0, "{workers} workers");
        // 1 High + 4 fillers + `workers` wedges complete
        assert_eq!(out.completed_jobs, 5 + workers as u64, "{workers} workers");
        assert_eq!(out.responses, out.requests, "serving must not notice");
    }
}

#[test]
fn edf_deadline_laws_hold_at_any_worker_count() {
    // the DeadlineBurst is built so rank r (0 = earliest due) dequeues
    // at wedge-release + job_cost*(r+1), inside its deadline of
    // job_cost*(r+2) — a lane law, not a timing accident. Only the
    // wedge count varies with workers: completed = workers + jobs.
    let base = named(42, "edf-beats-fifo");
    for workers in [1usize, 2, 4] {
        let mut sc = base.clone();
        sc.fit_workers = workers;
        let out = run(&sc).expect("scenario runs");
        assert_eq!(out.deadline_jobs, 4, "{workers} workers");
        assert_eq!(out.deadline_met_jobs, 4, "{workers} workers");
        assert_eq!(out.expired_jobs, 0, "{workers} workers");
        assert_eq!(out.failed_jobs, 0, "{workers} workers");
        assert_eq!(out.rejected_jobs, 0, "{workers} workers");
        assert_eq!(out.completed_jobs, 4 + workers as u64, "{workers} workers");
        assert_eq!(out.responses, out.requests, "serving must not notice");
    }
}

#[test]
fn deficit_round_robin_protects_the_victim_tenant_across_seeds() {
    // the fairness win is a policy property, not a seed accident: under
    // a standing backlog the FirstSeen victim waits its global FIFO
    // position, while DRR serves its (short) per-model queue every flush
    for seed in [7u64, 42] {
        let fs = run(&named(seed, "flooding-tenant-firstseen")).expect("runs");
        let dr = run(&named(seed, "flooding-tenant-fairness")).expect("runs");
        assert_eq!(fs.requests, dr.requests, "seed {seed}: same arrivals");
        assert_eq!(fs.responses, fs.requests, "seed {seed}: nothing lost");
        assert_eq!(dr.responses, dr.requests, "seed {seed}: nothing lost");
        let fs_p99 = fs.victim_p99_us.expect("victim tracked");
        let dr_p99 = dr.victim_p99_us.expect("victim tracked");
        assert!(
            fs_p99 > dr_p99,
            "seed {seed}: FirstSeen victim p99 {fs_p99} must exceed DRR {dr_p99}"
        );
    }
}

#[test]
fn queue_saturation_rejections_follow_capacity_exactly() {
    let base = named(7, "queue-saturation");
    for workers in [1usize, 2, 3] {
        let mut sc = base.clone();
        sc.fit_workers = workers;
        let out = run(&sc).expect("scenario runs");
        // `workers` wedges occupy every worker before the 6-job burst
        // lands; the bounded channel (capacity 4) accepts 4 - workers of
        // the burst and rejects the rest — machine speed never enters
        assert_eq!(
            out.rejected_jobs,
            (workers + 6 - 4) as u64,
            "{workers} workers"
        );
        assert_eq!(out.completed_jobs, 4, "{workers} workers");
        assert_eq!(out.failed_jobs, 0);
        assert_eq!(out.responses, out.requests, "serving must not notice");
    }
}

#[test]
fn client_stall_defers_arrivals_into_a_catchup_burst() {
    let base = named(42, "client-stall");
    let stalled = run(&base).expect("scenario runs");
    assert_eq!(stalled, run(&base).expect("second run"), "deterministic");
    // the same workload without the stall: same requests served, but
    // the catch-up burst after the stall fills batches far deeper than
    // the steady stream does
    let mut no_stall = base.clone();
    no_stall.faults.clear();
    let plain = run(&no_stall).expect("scenario runs");
    assert_eq!(plain.requests, stalled.requests, "no arrivals lost");
    assert_eq!(stalled.responses, stalled.requests);
    assert!(
        stalled.mean_batch > plain.mean_batch,
        "catch-up burst must deepen batches: {} vs {}",
        stalled.mean_batch,
        plain.mean_batch
    );
}

// ---------------------------------------------------------------------
// contract 4: workload generator laws (property tests)
// ---------------------------------------------------------------------

fn random_curve(rng: &mut Rng) -> RateCurve {
    match rng.below(3) {
        0 => RateCurve::Constant {
            rps: 200.0 + rng.uniform() * 3_000.0,
        },
        1 => RateCurve::Diurnal {
            base_rps: 100.0 + rng.uniform() * 500.0,
            peak_rps: 1_000.0 + rng.uniform() * 4_000.0,
            period: SECOND / 4 + rng.below(4) as u64 * (SECOND / 4),
        },
        _ => RateCurve::Bursty {
            on_rps: 1_000.0 + rng.uniform() * 4_000.0,
            off_rps: rng.uniform() * 200.0,
            on: SECOND / 8 + rng.below(3) as u64 * (SECOND / 8),
            off: SECOND / 8 + rng.below(5) as u64 * (SECOND / 8),
        },
    }
}

#[test]
fn same_spec_and_seed_give_bit_identical_streams() {
    testkit::check(
        "simserve-stream-bit-identical",
        0xB17,
        24,
        |rng| {
            let spec = WorkloadSpec {
                curve: random_curve(rng),
                horizon: SECOND / 4 + rng.below(4) as u64 * (SECOND / 4),
                models: 1 + rng.below(6),
                zipf_exponent: rng.uniform() * 1.5,
                d: 16 + rng.below(64),
                max_nnz: 1 + rng.below(10),
                proba_fraction: 0.0,
            };
            let seed = rng.below(1 << 30) as u64;
            (spec, seed)
        },
        |(spec, seed)| {
            let a = spec.generate(*seed);
            if a != spec.generate(*seed) {
                return Err("same spec + seed must be bit-identical".into());
            }
            if !a.is_empty() && a == spec.generate(seed.wrapping_add(1)) {
                return Err("different seed should change the stream".into());
            }
            for w in a.windows(2) {
                if w[0].at > w[1].at {
                    return Err("arrivals must be time-ordered".into());
                }
            }
            for arr in &a {
                if arr.at >= spec.horizon || arr.model >= spec.models {
                    return Err(format!("arrival out of range: {arr:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn arrival_counts_integrate_the_rate_curve() {
    testkit::check(
        "simserve-rate-integral",
        0x1A7,
        20,
        |rng| {
            let curve = random_curve(rng);
            let horizon = SECOND + rng.below(3) as u64 * SECOND;
            let seed = rng.below(1 << 30) as u64;
            (curve, horizon, seed)
        },
        |(curve, horizon, seed)| {
            let mut rng = Rng::new(*seed);
            let n = arrivals(curve, *horizon, &mut rng).len() as f64;
            let want = curve.expected_total(*horizon);
            // Poisson count: 6 sigma + slack is a ~1e-9 false-positive
            let tol = 6.0 * want.sqrt() + 20.0;
            if (n - want).abs() > tol {
                return Err(format!("{curve:?}: {n} arrivals, expected {want:.1} ± {tol:.1}"));
            }
            Ok(())
        },
    );
}

#[test]
fn zipf_tail_matches_its_exponent() {
    testkit::check(
        "simserve-zipf-tail",
        0x21F,
        8,
        |rng| {
            let n = 3 + rng.below(10);
            let s = 0.5 + rng.uniform();
            let seed = rng.below(1 << 30) as u64;
            (n, s, seed)
        },
        |&(n, s, seed)| {
            let z = Zipf::new(n, s);
            // the constructed pmf IS the Zipf law: p(0)/p(k) = (k+1)^s
            for k in 1..n {
                let want = ((k + 1) as f64).powf(s);
                let got = z.pmf(0) / z.pmf(k);
                if (got / want - 1.0).abs() > 1e-9 {
                    return Err(format!("pmf ratio {got} != (k+1)^s = {want} at k={k}"));
                }
            }
            // and draws follow it: head/tail frequency ratios within
            // 25% of the law over 200k samples
            let mut rng = Rng::new(seed);
            let mut freq = vec![0u64; n];
            for _ in 0..200_000 {
                freq[z.draw(&mut rng)] += 1;
            }
            for k in [1, n - 1] {
                let want = ((k + 1) as f64).powf(s);
                let got = freq[0] as f64 / freq[k].max(1) as f64;
                if (got / want - 1.0).abs() > 0.25 {
                    return Err(format!(
                        "freq ratio {got:.3} vs law {want:.3} at k={k} (n={n}, s={s:.3})"
                    ));
                }
            }
            Ok(())
        },
    );
}
