//! Portfolio racing engine: cancellation semantics.
//!
//! The contracts pinned here (referenced from
//! `coordinator/portfolio.rs` docs):
//!
//! 1. **Forced-winner bit-identity** — a race whose winner is forced to
//!    a deterministic member returns that member's standalone result
//!    bit-for-bit: the shared stop flag is only ever raised by the
//!    forced member itself (after it finishes), so the losers cannot
//!    perturb its trajectory.
//! 2. **Losers observe the flag and exit early** — a pre-raised
//!    external stop cancels every member kind (exact, atomic, sharded,
//!    CDN) far below its iteration budget; this is the same
//!    `Recorder::out_of_budget` poll the race winner relies on.
//! 3. **No detached threads** — `std::thread::scope` joins every racing
//!    thread before `solve_cd` returns; the OS thread count is back to
//!    its pre-race value at return (Linux, `/proc/self/status`).
//! 4. **Online P adaptation is observation-only for the sharded
//!    engine** — `adapt_p_every > 0` resizes the live worker subset at
//!    merge boundaries, so the trajectory stays bit-identical to the
//!    exact engine; the atomic path (which resizes for real) still
//!    reaches the KKT optimum.
//! 5. **Front door** — `Engine::Portfolio` through `api::Fit` attaches
//!    the race report, and an externally cancelled fit surfaces
//!    `ShotgunError::Cancelled` instead of a silent partial result.

use shotgun::api::{Engine, Fit, ShotgunError};
use shotgun::coordinator::{
    AccumulatorMode, MemberConfig, MemberKind, Portfolio, ShotgunConfig, ShotgunExact,
    ShotgunThreaded,
};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::solvers::common::{SolveOptions, StopFlag};

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (j, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: x[{j}] differs ({x} vs {y})");
    }
}

#[cfg(target_os = "linux")]
fn os_thread_count() -> usize {
    std::fs::read_to_string("/proc/self/status")
        .expect("/proc/self/status readable")
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line present")
        .trim()
        .parse()
        .expect("thread count parses")
}

#[test]
fn forced_winner_bit_identical_to_standalone() {
    let ds = synth::sparse_imaging(60, 120, 0.08, 3);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let x0 = vec![0.0; 120];
    let opts = SolveOptions {
        max_iters: 300_000,
        tol: 1e-8,
        ..Default::default()
    };
    let df = ShotgunConfig::default().divergence_factor;
    // every deterministic member kind takes a turn as the forced winner
    let members = vec![
        MemberConfig {
            kind: MemberKind::Exact,
            p: 4,
        },
        MemberConfig {
            kind: MemberKind::ThreadedSharded,
            p: 4,
        },
        MemberConfig {
            kind: MemberKind::Cdn,
            p: 2,
        },
    ];
    for forced in 0..members.len() {
        let tag = members[forced].label();
        let standalone = members[forced].solve(&prob, &x0, &opts, df);
        assert!(standalone.converged, "{tag}: standalone must converge");

        let mut port = Portfolio::new(members.clone());
        port.forced_winner = Some(forced);
        let raced = port.solve_cd(&prob, &x0, &opts);

        assert_eq!(raced.solver, format!("portfolio[{}]", standalone.solver));
        assert_eq!(raced.iters, standalone.iters, "{tag}: iters");
        assert_eq!(raced.updates, standalone.updates, "{tag}: updates");
        assert_eq!(raced.converged, standalone.converged, "{tag}: converged");
        assert_eq!(
            raced.objective.to_bits(),
            standalone.objective.to_bits(),
            "{tag}: objective {} vs {}",
            raced.objective,
            standalone.objective
        );
        assert_bits_eq(&raced.x, &standalone.x, &tag);

        let rep = port.report().expect("race leaves a report");
        assert_eq!(rep.winner_index, forced, "{tag}");
        assert_eq!(rep.winner, members[forced].label());
        assert_eq!(rep.losers.len(), members.len() - 1);
        for l in &rep.losers {
            assert_ne!(l.label, rep.winner);
            assert!(l.objective.is_finite(), "{}: loser objective", l.label);
        }
    }
}

#[test]
fn pre_raised_stop_cancels_every_member_kind() {
    // tol = 0 makes convergence impossible and max_iters is set far out
    // of reach, so the ONLY way any member (or the race) returns
    // quickly is the cooperative stop-flag poll — one per round/epoch
    // in the synchronous engines, one per monitor wake in the atomic
    // engine. The caller's external flag is bridged into the race flag
    // by the portfolio's main thread.
    let ds = synth::sparse_imaging(40, 80, 0.1, 7);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let x0 = vec![0.0; 80];
    let ext = StopFlag::new();
    ext.raise();
    let max_iters = 5_000_000u64;
    let opts = SolveOptions {
        max_iters,
        tol: 0.0,
        stop: ext.clone(),
        ..Default::default()
    };
    let mut port = Portfolio::new(
        [
            MemberKind::Exact,
            MemberKind::ThreadedAtomic,
            MemberKind::ThreadedSharded,
            MemberKind::Cdn,
        ]
        .into_iter()
        .map(|kind| MemberConfig { kind, p: 2 })
        .collect(),
    );
    let res = port.solve_cd(&prob, &x0, &opts);
    assert!(res.solver.starts_with("portfolio["), "{}", res.solver);
    assert!(!res.converged, "cancelled race must not claim convergence");
    assert!(
        res.iters < max_iters,
        "salvage winner ran to budget instead of observing the stop"
    );
    let rep = port.report().expect("cancelled race still reports");
    assert_eq!(rep.losers.len(), 3);
    for l in &rep.losers {
        assert!(!l.converged, "{}: cancelled loser converged?", l.label);
        assert!(
            l.iters_at_cancel < max_iters,
            "{}: ran to budget ({}) instead of observing the stop",
            l.label,
            l.iters_at_cancel
        );
    }
}

#[cfg(target_os = "linux")]
#[test]
fn racing_threads_all_joined_before_return() {
    let ds = synth::sparse_imaging(40, 80, 0.1, 5);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let x0 = vec![0.0; 80];
    let opts = SolveOptions {
        max_iters: 200_000,
        tol: 1e-6,
        ..Default::default()
    };
    let before = os_thread_count();
    let mut port = Portfolio::new(
        [
            MemberKind::Exact,
            MemberKind::ThreadedAtomic,
            MemberKind::ThreadedSharded,
            MemberKind::Cdn,
        ]
        .into_iter()
        .map(|kind| MemberConfig { kind, p: 2 })
        .collect(),
    );
    // a leaked thread per race would accumulate monotonically; scoped
    // threads are joined synchronously inside solve_cd, so the count
    // settles back to the baseline. (Other tests run concurrently under
    // the default harness, so poll with a grace window instead of
    // demanding instant equality.)
    for round in 0..3 {
        let res = port.solve_cd(&prob, &x0, &opts);
        assert!(res.objective.is_finite(), "round {round}");
    }
    let mut after = os_thread_count();
    for _ in 0..500 {
        if after <= before {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        after = os_thread_count();
    }
    assert!(
        after <= before,
        "racing threads must all be joined before solve_cd returns \
         (before {before}, after {after})"
    );
}

#[test]
fn sharded_adapt_resize_keeps_exact_bit_identity() {
    // the online-P controller on the sharded engine resizes the LIVE
    // worker subset only; draws, snapshot semantics, and the canonical
    // merge order never change, so the adaptive run is still
    // bit-identical to the exact engine — resizing is unobservable in
    // the trajectory
    let ds = synth::sparse_imaging(60, 120, 0.08, 3);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let x0 = vec![0.0; 120];
    let base = SolveOptions {
        max_iters: 300_000,
        tol: 1e-8,
        ..Default::default()
    };
    let ex = ShotgunExact::new(ShotgunConfig {
        p: 4,
        ..Default::default()
    })
    .solve_lasso(&prob, &x0, &base);
    let sh_opts = SolveOptions {
        accumulator: AccumulatorMode::Sharded { threads: 3 },
        adapt_p_every: 2,
        ..base
    };
    let sh = ShotgunThreaded::new(ShotgunConfig {
        p: 4,
        ..Default::default()
    })
    .solve_lasso(&prob, &x0, &sh_opts);
    assert!(sh.solver.ends_with("-sharded-adapt"), "{}", sh.solver);
    assert_eq!(ex.iters, sh.iters);
    assert_eq!(ex.updates, sh.updates);
    assert_eq!(ex.converged, sh.converged);
    assert_eq!(ex.objective.to_bits(), sh.objective.to_bits());
    assert_bits_eq(&ex.x, &sh.x, "adaptive sharded vs exact");
}

#[test]
fn atomic_adapt_reaches_the_optimum() {
    // the atomic path resizes for real (workers parked behind the
    // p_live gate); the contract there is convergence, not determinism
    let ds = synth::sparse_imaging(60, 120, 0.08, 9);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
    let opts = SolveOptions {
        max_iters: 300_000,
        tol: 1e-8,
        adapt_p_every: 3,
        ..Default::default()
    };
    let res = ShotgunThreaded::new(ShotgunConfig {
        p: 2,
        ..Default::default()
    })
    .solve_lasso(&prob, &vec![0.0; 120], &opts);
    assert!(res.solver.ends_with("-adapt"), "{}", res.solver);
    let r = prob.residual(&res.x);
    assert!(
        prob.kkt_violation(&res.x, &r) < 1e-4,
        "kkt {}",
        prob.kkt_violation(&res.x, &r)
    );
}

#[test]
fn engine_portfolio_end_to_end_attaches_race_report() {
    let ds = synth::sparse_imaging(60, 120, 0.08, 3);
    let report = Fit::new(&ds.design, &ds.targets)
        .lambda(0.1)
        .engine(Engine::Portfolio)
        .options(|o| {
            o.max_iters = 300_000;
            o.tol = 1e-7;
            o.seed = 9;
        })
        .run()
        .expect("portfolio fit solves");
    assert!(
        report.diagnostics.solver.starts_with("portfolio["),
        "{}",
        report.diagnostics.solver
    );
    assert!(report.converged());
    let race = report.portfolio.as_ref().expect("race report attached");
    assert!(!race.winner.is_empty());
    assert!(race.losers.iter().all(|l| l.label != race.winner));
    // winner + losers account for the whole roster (labels unique)
    let mut labels: Vec<&str> = race.losers.iter().map(|l| l.label.as_str()).collect();
    labels.push(race.winner.as_str());
    labels.sort_unstable();
    labels.dedup();
    assert_eq!(labels.len(), race.losers.len() + 1);
}

#[test]
fn fit_external_stop_surfaces_cancelled_error() {
    // a pre-raised caller flag cancels the solve before convergence;
    // the front door refuses to hand back the partial iterate as if it
    // were a fit
    let ds = synth::sparse_imaging(40, 80, 0.1, 11);
    let ext = StopFlag::new();
    ext.raise();
    let err = Fit::new(&ds.design, &ds.targets)
        .lambda(0.1)
        .solver("shotgun")
        .options(|o| {
            o.max_iters = 100_000;
            o.tol = 0.0;
            o.stop = ext.clone();
        })
        .run()
        .expect_err("cancelled fit must error");
    match &err {
        ShotgunError::Cancelled { solver } => assert!(!solver.is_empty()),
        other => panic!("expected Cancelled, got {other}"),
    }
    assert!(err.to_string().contains("cancelled"), "{err}");
}
