//! Golden-fixture convergence regressions.
//!
//! `tests/fixtures/*.json` are small seeded problems whose optimal
//! objective `f_star` was computed by an INDEPENDENT reference
//! implementation (`scripts/make_fixtures.py`, numpy cyclic CD run to
//! near machine precision, KKT-verified) — not by any solver in this
//! crate. Every registered exact-optimum solver must reach `f_star`
//! within [`REL_TOL`]. The bit-identity proptests can't catch a
//! regression that changes *all* solvers the same way (an objective
//! convention slip, a step-size bug in the shared `CdObjective` layer);
//! an externally pinned optimum can.

use shotgun::api::{IterUnit, ProblemRef, SolverParams, SolverRegistry};
use shotgun::coordinator::{
    AccumulatorMode, SchedulePolicy, ShotgunConfig, ShotgunExact, ShotgunThreaded,
};
use shotgun::objective::{
    CdObjective, HuberProblem, LassoProblem, LogisticProblem, Loss, SqHingeProblem,
};
use shotgun::solvers::common::SolveOptions;
use shotgun::sparsela::{DenseMatrix, Design};
use shotgun::util::json::Json;
use std::path::PathBuf;

/// Documented tolerance: a registered exact-optimum solver must land
/// within this relative objective gap of the fixture optimum, given the
/// generous budgets below. (The fixtures themselves are accurate to
/// ~1e-15 relative; the slack is for the solvers, not the pins.)
const REL_TOL: f64 = 1e-4;

/// How tightly the fixture's own `x_star`/`f_star` pair must agree when
/// re-evaluated through this crate's objective code — this is the
/// convention check (0.5 factor, log1p form, lambda scaling).
const PIN_TOL: f64 = 1e-9;

struct Fixture {
    name: String,
    loss: Loss,
    design: Design,
    targets: Vec<f64>,
    lam: f64,
    x_star: Vec<f64>,
    f_star: f64,
}

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn load_fixture(file: &str) -> Fixture {
    let path = fixtures_dir().join(file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let doc = Json::parse(&text).expect("fixture is valid JSON");
    assert_eq!(
        doc.get("format").and_then(Json::as_str),
        Some("shotgun.fixture.v1"),
        "{file}: unknown fixture format"
    );
    let num_vec = |key: &str| -> Vec<f64> {
        doc.get(key)
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("{file}: missing array {key}"))
            .iter()
            .map(|v| v.as_f64().expect("numeric array"))
            .collect()
    };
    let n = doc.get("n").and_then(Json::as_usize).expect("n");
    let d = doc.get("d").and_then(Json::as_usize).expect("d");
    let col_major = num_vec("col_major");
    assert_eq!(col_major.len(), n * d, "{file}: design size");
    Fixture {
        name: doc
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(file)
            .to_string(),
        loss: doc
            .get("loss")
            .and_then(Json::as_str)
            .and_then(Loss::parse)
            .unwrap_or_else(|| {
                panic!("{file}: unknown loss {:?}", doc.get("loss").and_then(Json::as_str))
            }),
        design: Design::Dense(DenseMatrix::from_col_major(n, d, col_major)),
        targets: num_vec("targets"),
        lam: doc.get("lam").and_then(Json::as_f64).expect("lam"),
        x_star: num_vec("x_star"),
        f_star: doc.get("f_star").and_then(Json::as_f64).expect("f_star"),
    }
}

fn all_fixtures() -> Vec<Fixture> {
    [
        "lasso_small.json",
        "lasso_wide.json",
        "logistic_small.json",
        "logistic_wide.json",
        "sqhinge_small.json",
        "sqhinge_wide.json",
        "huber_small.json",
        "huber_wide.json",
    ]
    .iter()
    .map(|f| load_fixture(f))
    .collect()
}

/// Generous budgets per iteration unit — these tiny problems converge
/// orders of magnitude earlier; the point is that no exact solver may
/// NEED more.
fn opts_for(unit: IterUnit) -> SolveOptions {
    // note gpsr-bb/sparsa count single gradient/BB steps as one Sweep
    // unit — their own unit tests budget 20k on comparable sizes, so
    // stay well above that
    let max_iters = match unit {
        IterUnit::Update | IterUnit::Round => 500_000,
        IterUnit::Sweep => 40_000,
        IterUnit::Epoch => 500,
    };
    SolveOptions {
        max_iters,
        tol: 1e-10,
        record_every: 4_096,
        seed: 17,
        ..Default::default()
    }
}

#[test]
fn fixture_pins_match_this_crates_objective_conventions() {
    // if this fails, the crate's objective (or the fixture generator)
    // changed conventions — fix that before trusting the solver gate
    for fx in all_fixtures() {
        let f_here = match fx.loss {
            Loss::Squared => {
                LassoProblem::new(&fx.design, &fx.targets, fx.lam).objective(&fx.x_star)
            }
            Loss::Logistic => {
                LogisticProblem::new(&fx.design, &fx.targets, fx.lam).objective(&fx.x_star)
            }
            Loss::SqHinge => {
                SqHingeProblem::new(&fx.design, &fx.targets, fx.lam).objective(&fx.x_star)
            }
            Loss::Huber => {
                HuberProblem::new(&fx.design, &fx.targets, fx.lam).objective(&fx.x_star)
            }
        };
        let rel = (f_here - fx.f_star).abs() / fx.f_star.max(1.0);
        assert!(
            rel < PIN_TOL,
            "{}: crate objective at x_star = {f_here}, fixture f_star = {} (rel {rel:.2e})",
            fx.name,
            fx.f_star
        );
    }
}

#[test]
fn every_exact_solver_reaches_the_golden_optima() {
    let registry = SolverRegistry::global();
    let params = SolverParams {
        p: 2,
        ..Default::default()
    };
    for fx in all_fixtures() {
        let d = fx.design.d();
        let x0 = vec![0.0; d];
        let lasso;
        let logistic;
        let sqhinge;
        let huber;
        let prob = match fx.loss {
            Loss::Squared => {
                lasso = LassoProblem::new(&fx.design, &fx.targets, fx.lam);
                ProblemRef::Lasso(&lasso)
            }
            Loss::Logistic => {
                logistic = LogisticProblem::new(&fx.design, &fx.targets, fx.lam);
                ProblemRef::Logistic(&logistic)
            }
            Loss::SqHinge => {
                sqhinge = SqHingeProblem::new(&fx.design, &fx.targets, fx.lam);
                ProblemRef::SqHinge(&sqhinge)
            }
            Loss::Huber => {
                huber = HuberProblem::new(&fx.design, &fx.targets, fx.lam);
                ProblemRef::Huber(&huber)
            }
        };
        for entry in registry.entries() {
            if !entry.caps.exact_optimum || !entry.caps.supports(fx.loss) {
                continue;
            }
            let opts = opts_for(entry.caps.iter_unit);
            let mut solver = entry.create(&params);
            let res = solver
                .solve(prob, &x0, &opts)
                .unwrap_or_else(|e| panic!("{}: {} refused: {e}", fx.name, entry.name));
            let gap = (res.objective - fx.f_star) / fx.f_star.max(1.0);
            assert!(
                gap <= REL_TOL,
                "{}: {} converged to F = {} but the golden optimum is {} (rel gap {gap:.2e})",
                fx.name,
                entry.name,
                res.objective,
                fx.f_star
            );
            // nothing may (meaningfully) beat a KKT-verified optimum:
            // that would mean the solver optimizes a different objective
            assert!(
                gap >= -1e-8,
                "{}: {} reported F = {} BELOW the golden optimum {} — objective drift?",
                fx.name,
                entry.name,
                res.objective,
                fx.f_star
            );
        }
    }
}

/// The PR-6 engine knobs (sharded accumulator, clustered schedule) are
/// not separate registry entries — they are `SolveOptions` toggles on
/// the shotgun engines. Gate them against the same external optima.
fn check_gap(fx: &Fixture, tag: &str, objective: f64) {
    let gap = (objective - fx.f_star) / fx.f_star.max(1.0);
    assert!(
        gap <= REL_TOL,
        "{}: {tag} converged to F = {objective} but the golden optimum is {} (rel gap {gap:.2e})",
        fx.name,
        fx.f_star
    );
    assert!(
        gap >= -1e-8,
        "{}: {tag} reported F = {objective} BELOW the golden optimum {} — objective drift?",
        fx.name,
        fx.f_star
    );
}

fn for_each_fixture_objective(mut run: impl FnMut(&Fixture, &dyn Fn(&SolveOptions) -> f64)) {
    for fx in all_fixtures() {
        let x0 = vec![0.0; fx.design.d()];
        match fx.loss {
            Loss::Squared => {
                let p = LassoProblem::new(&fx.design, &fx.targets, fx.lam);
                run(&fx, &|o| solve_both(&p, &x0, o));
            }
            Loss::Logistic => {
                let p = LogisticProblem::new(&fx.design, &fx.targets, fx.lam);
                run(&fx, &|o| solve_both(&p, &x0, o));
            }
            Loss::SqHinge => {
                let p = SqHingeProblem::new(&fx.design, &fx.targets, fx.lam);
                run(&fx, &|o| solve_both(&p, &x0, o));
            }
            Loss::Huber => {
                let p = HuberProblem::new(&fx.design, &fx.targets, fx.lam);
                run(&fx, &|o| solve_both(&p, &x0, o));
            }
        }
    }
}

/// Solve with the engine the options select (exact for schedule-only
/// runs, threaded for sharded runs) and return the objective.
fn solve_both<O: CdObjective + Sync>(p: &O, x0: &[f64], opts: &SolveOptions) -> f64 {
    let cfg = ShotgunConfig {
        p: 2,
        ..Default::default()
    };
    if matches!(opts.accumulator, AccumulatorMode::Sharded { .. }) {
        ShotgunThreaded::new(cfg).solve_cd(p, x0, opts).objective
    } else {
        ShotgunExact::new(cfg).solve_cd(p, x0, opts).objective
    }
}

#[test]
fn sharded_accumulator_reaches_the_golden_optima() {
    let opts = SolveOptions {
        accumulator: AccumulatorMode::Sharded { threads: 3 },
        ..opts_for(IterUnit::Update)
    };
    for_each_fixture_objective(|fx, solve| check_gap(fx, "shotgun sharded", solve(&opts)));
}

#[test]
fn clustered_schedule_reaches_the_golden_optima() {
    let opts = SolveOptions {
        schedule: SchedulePolicy::Clustered { clusters: 0 },
        ..opts_for(IterUnit::Update)
    };
    for_each_fixture_objective(|fx, solve| check_gap(fx, "shotgun clustered", solve(&opts)));
}

#[test]
fn clustered_schedule_under_sharded_accumulator_reaches_the_golden_optima() {
    // the two knobs compose: clustered draws decide WHAT each round
    // touches, the sharded accumulator decides HOW the round commits
    let opts = SolveOptions {
        schedule: SchedulePolicy::Clustered { clusters: 0 },
        accumulator: AccumulatorMode::Sharded { threads: 2 },
        ..opts_for(IterUnit::Update)
    };
    for_each_fixture_objective(|fx, solve| {
        check_gap(fx, "shotgun clustered+sharded", solve(&opts))
    });
}
