//! The capability table can't drift from the solver implementations.
//!
//! PR 3's CLI and figure harnesses enumerate the registry's `fig3`/
//! `fig4` sets instead of hand-rolled solver lists — which means a
//! stale capability flag silently changes what the paper-comparison
//! benches run. This test RUNS each listed set against the loss the set
//! is defined over and asserts every member (a) declares support for
//! that loss, (b) actually solves it (no `LossUnsupported`, real
//! descent), so the table and the impls can't diverge.

use shotgun::api::{IterUnit, ProblemRef, SolverParams, SolverRegistry};
use shotgun::data::synth;
use shotgun::objective::{HuberProblem, LassoProblem, LogisticProblem, Loss, SqHingeProblem};
use shotgun::solvers::common::SolveOptions;

fn opts_for(unit: IterUnit) -> SolveOptions {
    let max_iters = match unit {
        IterUnit::Update | IterUnit::Round => 60_000,
        IterUnit::Sweep => 1_500,
        IterUnit::Epoch => 60,
    };
    SolveOptions {
        max_iters,
        tol: 1e-7,
        record_every: 1_024,
        seed: 13,
        ..Default::default()
    }
}

#[test]
fn fig3_set_solves_the_lasso_it_advertises() {
    // Fig. 3 is the published-Lasso-comparator set: every member must
    // declare the squared loss and descend on a real Lasso instance
    let reg = SolverRegistry::global();
    let ds = synth::sparse_imaging(40, 60, 0.15, 91);
    let prob = LassoProblem::new(&ds.design, &ds.targets, 0.2);
    let x0 = vec![0.0; 60];
    let f0 = prob.objective(&x0);
    let params = SolverParams {
        p: 2,
        ..Default::default()
    };
    let fig3: Vec<_> = reg.entries().iter().filter(|e| e.caps.fig3_lasso).collect();
    assert!(!fig3.is_empty(), "fig3 set vanished from the registry");
    for entry in fig3 {
        assert!(
            entry.caps.supports(Loss::Squared),
            "{}: in the fig3 (Lasso) set but does not declare the squared loss",
            entry.name
        );
        let res = entry
            .create(&params)
            .solve(ProblemRef::Lasso(&prob), &x0, &opts_for(entry.caps.iter_unit))
            .unwrap_or_else(|e| {
                panic!("{}: listed in fig3 but refused the Lasso: {e}", entry.name)
            });
        assert!(
            res.objective < f0,
            "{}: listed in fig3 but failed to descend (F = {} vs F(0) = {f0})",
            entry.name,
            res.objective
        );
    }
}

#[test]
fn fig4_set_solves_the_logistic_it_advertises() {
    let reg = SolverRegistry::global();
    let ds = synth::rcv1_like(50, 40, 0.2, 92);
    let prob = LogisticProblem::new(&ds.design, &ds.targets, 0.05);
    let x0 = vec![0.0; 40];
    let f0 = prob.objective(&x0);
    let params = SolverParams {
        p: 2,
        eta: 0.1,
        ..Default::default()
    };
    let fig4: Vec<_> = reg.entries().iter().filter(|e| e.caps.fig4_logreg).collect();
    assert!(!fig4.is_empty(), "fig4 set vanished from the registry");
    for entry in fig4 {
        assert!(
            entry.caps.supports(Loss::Logistic),
            "{}: in the fig4 (logistic) set but does not declare the logistic loss",
            entry.name
        );
        let res = entry
            .create(&params)
            .solve(
                ProblemRef::Logistic(&prob),
                &x0,
                &opts_for(entry.caps.iter_unit),
            )
            .unwrap_or_else(|e| {
                panic!("{}: listed in fig4 but refused the logistic loss: {e}", entry.name)
            });
        assert!(
            res.objective < f0,
            "{}: listed in fig4 but failed to descend (F = {} vs F(0) = {f0})",
            entry.name,
            res.objective
        );
    }
}

#[test]
fn every_advertised_loss_is_actually_solved() {
    // the generalization of the two set-specific checks above: for EVERY
    // entry and EVERY loss in its LossSet, the solver must accept the
    // problem (no LossUnsupported) and genuinely descend from x = 0.
    // Registering a loss a solver cannot run fails here, as does
    // dropping support a capability still advertises.
    let reg = SolverRegistry::global();
    let reg_ds = synth::sparco_like(40, 24, 0.35, 93);
    let cls_ds = synth::rcv1_like(40, 24, 0.3, 94);
    let lasso = LassoProblem::new(&reg_ds.design, &reg_ds.targets, 0.15);
    let huber = HuberProblem::new(&reg_ds.design, &reg_ds.targets, 0.05);
    let logistic = LogisticProblem::new(&cls_ds.design, &cls_ds.targets, 0.05);
    let sqhinge = SqHingeProblem::new(&cls_ds.design, &cls_ds.targets, 0.05);
    let x0 = vec![0.0; 24];
    let params = SolverParams {
        p: 2,
        eta: 0.05,
        ..Default::default()
    };
    for entry in reg.entries() {
        for loss in entry.caps.losses.iter() {
            let (prob, f0): (ProblemRef<'_, '_>, f64) = match loss {
                Loss::Squared => (ProblemRef::Lasso(&lasso), lasso.objective(&x0)),
                Loss::Logistic => (ProblemRef::Logistic(&logistic), logistic.objective(&x0)),
                Loss::SqHinge => (ProblemRef::SqHinge(&sqhinge), sqhinge.objective(&x0)),
                Loss::Huber => (ProblemRef::Huber(&huber), huber.objective(&x0)),
            };
            let res = entry
                .create(&params)
                .solve(prob, &x0, &opts_for(entry.caps.iter_unit))
                .unwrap_or_else(|e| {
                    panic!(
                        "{}: advertises {} but refused it: {e}",
                        entry.name,
                        loss.name()
                    )
                });
            assert!(
                res.objective < f0,
                "{}: advertises {} but failed to descend (F = {} vs F(0) = {f0})",
                entry.name,
                loss.name(),
                res.objective
            );
        }
        // and the dyn handle refuses what the capability table excludes
        for loss in Loss::ALL {
            if entry.caps.supports(loss) {
                continue;
            }
            let prob: ProblemRef<'_, '_> = match loss {
                Loss::Squared => ProblemRef::Lasso(&lasso),
                Loss::Logistic => ProblemRef::Logistic(&logistic),
                Loss::SqHinge => ProblemRef::SqHinge(&sqhinge),
                Loss::Huber => ProblemRef::Huber(&huber),
            };
            let err = entry
                .create(&params)
                .solve(prob, &x0, &opts_for(entry.caps.iter_unit))
                .expect_err("unadvertised loss must be refused");
            assert!(
                matches!(err, shotgun::api::ShotgunError::LossUnsupported { .. }),
                "{}: wrong refusal for {}: {err:?}",
                entry.name,
                loss.name()
            );
        }
    }
}

#[test]
fn rate_swept_solvers_are_all_sgd_family_and_non_exact() {
    // the sweep protocol only applies to constant-rate stochastic
    // solvers; an exact CD solver wandering into the rate-swept set
    // would get a meaningless eta sweep in the CLI
    let reg = SolverRegistry::global();
    for entry in reg.entries() {
        if entry.caps.rate_swept {
            assert!(
                !entry.caps.exact_optimum,
                "{}: rate-swept solvers are the SGD family (not exact optimizers)",
                entry.name
            );
            assert_eq!(
                entry.caps.iter_unit,
                IterUnit::Epoch,
                "{}: rate-swept solvers budget in epochs",
                entry.name
            );
        }
    }
}

#[test]
fn capability_sets_only_contain_supported_losses() {
    // cheap structural pass over EVERY entry (the solve-based checks
    // above cover the two named sets): a set membership or loss flag
    // combination that cannot work is caught here without a solve
    let reg = SolverRegistry::global();
    for entry in reg.entries() {
        let caps = &entry.caps;
        assert!(
            !caps.losses.is_empty(),
            "{}: registered solver supports no loss at all",
            entry.name
        );
        if caps.fig3_lasso {
            assert!(
                caps.supports(Loss::Squared),
                "{}: fig3 implies squared",
                entry.name
            );
        }
        if caps.fig4_logreg {
            assert!(
                caps.supports(Loss::Logistic),
                "{}: fig4 implies logistic",
                entry.name
            );
        }
        if caps.pathwise_warmstart {
            // strong-rule screening assumes an exact KKT optimum to
            // re-check against
            assert!(
                caps.exact_optimum,
                "{}: pathwise warm-start screening needs an exact optimizer",
                entry.name
            );
        }
    }
}
