//! Property tests for `api::Model` JSON round-trips at the edges of
//! f64, plus display coverage for every `ShotgunError` variant.
//!
//! The serving story rests on "a model survives JSON bit-for-bit";
//! these tests push that claim where shortest-round-trip float
//! formatting is most likely to crack: subnormals, `MAX`-magnitude
//! weights, exact zeros (dropped from storage), and models whose
//! feature tail is all zeros (d must survive without any weight
//! mentioning it).

use shotgun::api::serve::PredictRequest;
use shotgun::api::{Model, ShotgunError};
use shotgun::objective::Loss;
use shotgun::testkit;
use shotgun::util::rng::Rng;

/// Weight values that stress the serializer: exact zero (not stored),
/// subnormals, near-MAX magnitudes, sub-ZERO_TOL dust, and ordinary
/// values.
fn edge_weight(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => f64::MIN_POSITIVE,            // smallest normal
        2 => 5e-324,                       // smallest subnormal
        3 => 1e-310 * rng.range(0.5, 2.0), // random subnormal
        4 => f64::MAX * rng.range(0.5, 1.0),
        5 => -f64::MAX * rng.range(0.5, 1.0),
        6 => 1e-12 * rng.normal(), // below ZERO_TOL, still stored
        _ => rng.normal(),
    }
}

#[test]
fn json_roundtrip_is_bit_exact_at_f64_edges() {
    testkit::check(
        "model-json-roundtrip-edges",
        2027,
        150,
        |rng| {
            let d = 1 + rng.below(40);
            let x: Vec<f64> = (0..d).map(|_| edge_weight(rng)).collect();
            let loss = if rng.bernoulli(0.5) {
                Loss::Squared
            } else {
                Loss::Logistic
            };
            let lam = rng.range(0.0, 2.0);
            (x, loss, lam)
        },
        |(x, loss, lam)| {
            let m = Model::from_dense(x, *loss, *lam, "edge-test");
            let m2 = Model::from_json(&m.to_json())
                .map_err(|e| format!("roundtrip parse failed: {e}"))?;
            if m2 != m {
                return Err("roundtrip not equal".into());
            }
            for (&(j1, v1), &(j2, v2)) in m.weights().iter().zip(m2.weights()) {
                if j1 != j2 || v1.to_bits() != v2.to_bits() {
                    return Err(format!(
                        "weight ({j1}, {v1:e}) came back as ({j2}, {v2:e})"
                    ));
                }
            }
            // dense reconstruction is lossless, zeros included
            if m2.to_dense() != *x {
                return Err("to_dense != original".into());
            }
            Ok(())
        },
    );
}

#[test]
fn zero_weight_model_roundtrips_and_predicts_zero() {
    let m = Model::from_dense(&[0.0; 7], Loss::Squared, 0.5, "all-zero");
    assert_eq!(m.weights().len(), 0);
    assert_eq!(m.nnz(), 0);
    assert_eq!(m.d(), 7);
    let m2 = Model::from_json(&m.to_json()).expect("roundtrip");
    assert_eq!(m2, m);
    assert_eq!(m2.d(), 7, "d survives with no stored weight");
    // and it serves: every prediction is exactly 0.0
    let req = PredictRequest::new(vec![(0, 3.5), (6, -1.0)]);
    let a = shotgun::api::serve::batch_design(&[req], 7).unwrap();
    assert_eq!(m2.predict(&a).unwrap(), vec![0.0]);
}

#[test]
fn empty_feature_tail_preserves_dimension() {
    // last nonzero far before d: idx/val never mention the tail, so a
    // sloppy parser would shrink d and break dimension checks
    let mut x = vec![0.0; 64];
    x[2] = -1.25;
    x[5] = 1e-200;
    let m = Model::from_dense(&x, Loss::Logistic, 0.1, "tail");
    let m2 = Model::from_json(&m.to_json()).expect("roundtrip");
    assert_eq!(m2.d(), 64);
    assert_eq!(m2.to_dense(), x);
    // an index AT d is rejected (boundary of the tail)
    let doc = m.to_json().replace("\"idx\":[2,5]", "\"idx\":[2,64]");
    assert!(matches!(
        Model::from_json(&doc),
        Err(ShotgunError::ModelFormat { .. })
    ));
    // a FRACTIONAL index is rejected, not truncated onto feature 2
    let doc = m.to_json().replace("\"idx\":[2,5]", "\"idx\":[2.5,5]");
    assert!(matches!(
        Model::from_json(&doc),
        Err(ShotgunError::ModelFormat { .. })
    ));
    // and a fractional d is rejected, not truncated
    let doc = m.to_json().replace("\"d\":64", "\"d\":64.7");
    assert!(matches!(
        Model::from_json(&doc),
        Err(ShotgunError::ModelFormat { .. })
    ));
}

#[test]
fn subnormal_and_max_weights_survive_explicit_probes() {
    // the proptest samples these; this pins the exact cases by name so
    // a failure is immediately legible
    for &v in &[
        5e-324,
        -5e-324,
        f64::MIN_POSITIVE,
        f64::MAX,
        -f64::MAX,
        1.0 + f64::EPSILON,
    ] {
        let m = Model::from_dense(&[v], Loss::Squared, 0.1, "probe");
        let m2 = Model::from_json(&m.to_json())
            .unwrap_or_else(|e| panic!("weight {v:e} failed to roundtrip: {e}"));
        assert_eq!(
            m2.weights()[0].1.to_bits(),
            v.to_bits(),
            "weight {v:e} changed bits"
        );
    }
}

// ---------------------------------------------------------------------
// ShotgunError display / source coverage
// ---------------------------------------------------------------------

/// One of every variant, with recognizable payloads.
fn all_variants() -> Vec<(ShotgunError, &'static str)> {
    vec![
        (ShotgunError::EmptyDesign { n: 0, d: 5 }, "empty design"),
        (
            ShotgunError::DimensionMismatch {
                what: "targets",
                expected: 10,
                got: 7,
            },
            "targets",
        ),
        (
            ShotgunError::NonFinite {
                what: "warm start",
                index: 3,
                value: f64::NAN,
            },
            "warm start",
        ),
        (
            ShotgunError::BadLabel {
                index: 2,
                value: 0.5,
            },
            "labels",
        ),
        (
            ShotgunError::InvalidLambda {
                lam: -1.0,
                reason: "lambda must be finite and non-negative",
            },
            "lambda",
        ),
        (
            ShotgunError::InvalidParam {
                name: "huber_delta",
                value: -0.5,
                reason: "delta must be finite and positive",
            },
            "huber_delta",
        ),
        (
            ShotgunError::InvalidPath {
                reason: "stages must be >= 1".into(),
            },
            "path",
        ),
        (
            ShotgunError::UnknownSolver {
                name: "shotgnu".into(),
                known: vec!["shotgun"],
            },
            "shotgnu",
        ),
        (
            ShotgunError::LossUnsupported {
                solver: "l1-ls".into(),
                loss: Loss::Logistic,
            },
            "logistic",
        ),
        (
            ShotgunError::ProbaUnsupported {
                loss: Loss::Squared,
            },
            "predict_proba",
        ),
        (
            ShotgunError::BudgetExhausted {
                iters: 42,
                seconds: 1.5,
                objective: 3.0,
            },
            "budget",
        ),
        (
            ShotgunError::Cancelled {
                solver: "portfolio[shotgun-threaded-p4-sharded]".into(),
            },
            "cancelled",
        ),
        (
            ShotgunError::ModelFormat {
                reason: "missing field \"d\"".into(),
            },
            "model",
        ),
        (
            ShotgunError::Io {
                path: "store_dir/m.store.json".into(),
                reason: "write: permission denied".into(),
            },
            "i/o",
        ),
        (
            ShotgunError::UnknownModel {
                name: "ghost".into(),
                known: vec!["default".into()],
            },
            "ghost",
        ),
        (
            ShotgunError::BadRequest {
                index: 9,
                reason: "feature index 99 out of range".into(),
            },
            "request",
        ),
        (ShotgunError::QueueClosed, "queue"),
        (
            ShotgunError::JobPanicked {
                reason: "index out of bounds".into(),
            },
            "panic",
        ),
        (ShotgunError::ServerShutdown, "shut down"),
        (
            ShotgunError::Overloaded {
                in_flight: 128,
                limit: 64,
            },
            "overloaded",
        ),
        (ShotgunError::DeadlineExpired { late: 77 }, "deadline"),
    ]
}

#[test]
fn every_error_variant_displays_its_payload() {
    let variants = all_variants();
    let mut rendered = Vec::new();
    for (err, marker) in &variants {
        let s = err.to_string();
        assert!(!s.is_empty());
        assert!(
            s.to_lowercase().contains(marker),
            "{err:?} display {s:?} does not mention {marker:?}"
        );
        rendered.push(s);
    }
    // each variant renders distinctly — no two collapse to one message
    let mut unique = rendered.clone();
    unique.sort();
    unique.dedup();
    assert_eq!(unique.len(), variants.len(), "duplicate display strings");
}

#[test]
fn error_chains_compose_with_std_and_util_error() {
    // ShotgunError is a leaf: no wrapped source, and the Display string
    // carries everything a caller needs to log
    for (err, _) in all_variants() {
        let as_std: &dyn std::error::Error = &err;
        assert!(as_std.source().is_none(), "{err:?} grew a source");
        // boxed trait-object round trip (the common logging path)
        let boxed: Box<dyn std::error::Error + Send + Sync> = Box::new(err.clone());
        assert_eq!(boxed.to_string(), err.to_string());
        // conversion into the crate's string-backed runtime error
        // preserves the message
        let util: shotgun::util::err::Error = err.clone().into();
        assert_eq!(util.to_string(), err.to_string());
    }
}

#[test]
fn unknown_model_display_handles_empty_store() {
    let e = ShotgunError::UnknownModel {
        name: "m".into(),
        known: vec![],
    };
    assert!(e.to_string().contains("store is empty"), "{e}");
}
