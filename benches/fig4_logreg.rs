//! Bench: regenerate Fig. 4 (logistic regression, objective/error vs time).
//! `cargo bench --bench fig4_logreg` — scale via SHOTGUN_BENCH_SCALE.

use shotgun::bench::{fig4, BenchConfig};

fn main() {
    let cfg = BenchConfig {
        scale: std::env::var("SHOTGUN_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.15),
        max_seconds: 20.0,
        ..Default::default()
    };
    fig4::run(&cfg);
}
