//! Bench: regenerate Fig. 2 (iterations-to-tolerance vs P, two rho regimes).
//! `cargo bench --bench fig2_pstar` — scale via SHOTGUN_BENCH_SCALE.

use shotgun::bench::{fig2, BenchConfig};

fn main() {
    let cfg = BenchConfig {
        scale: std::env::var("SHOTGUN_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.15),
        ..Default::default()
    };
    fig2::run(&cfg);
}
