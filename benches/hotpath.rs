//! Microbenchmarks of the L3 hot paths (criterion-substitute harness):
//! the per-update column kernels, one synchronous Shotgun round, the
//! threaded engine's CAS loop, and the XLA block-round dispatch.
//!
//! `cargo bench --bench hotpath` — these are the §Perf regression gates.

use shotgun::coordinator::atomic::AtomicVec;
use shotgun::coordinator::{ShotgunConfig, ShotgunExact};
use shotgun::data::synth;
use shotgun::metrics::harness::{bench_for, black_box};
use shotgun::objective::LassoProblem;
use shotgun::util::rng::Rng;

fn main() {
    let mut results = Vec::new();

    // --- sparse column kernels (the per-update cost) ---
    {
        let ds = synth::sparse_imaging(4096, 8192, 0.01, 1);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
        let r = prob.residual(&vec![0.0; 8192]);
        let mut rng = Rng::new(2);
        results.push(bench_for("col_dot sparse (n=4096, ~41 nnz)", 0.5, 64, || {
            let j = rng.below(8192);
            black_box(ds.design.col_dot(j, &r))
        }));
        let mut r2 = r.clone();
        let mut rng2 = Rng::new(3);
        results.push(bench_for("col_axpy sparse", 0.5, 64, || {
            let j = rng2.below(8192);
            ds.design.col_axpy(j, 1e-9, &mut r2);
        }));
    }

    // --- dense column kernels ---
    {
        let ds = synth::singlepix_pm1(1024, 2048, 4);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
        let r = prob.residual(&vec![0.0; 2048]);
        let mut rng = Rng::new(5);
        results.push(bench_for("col_dot dense (n=1024)", 0.5, 64, || {
            let j = rng.below(2048);
            black_box(ds.design.col_dot(j, &r))
        }));
    }

    // --- one synchronous Shotgun round (P=8) ---
    {
        let ds = synth::sparse_imaging(2048, 4096, 0.01, 6);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.05);
        let engine = ShotgunExact::new(ShotgunConfig {
            p: 8,
            ..Default::default()
        });
        let mut x = vec![0.0; 4096];
        let mut r = prob.residual(&x);
        let mut rng = Rng::new(7);
        let mut draws = Vec::new();
        let mut deltas = Vec::new();
        results.push(bench_for("shotgun_round P=8 (sparse 2048x4096)", 1.0, 64, || {
            engine.lasso_round(&prob, &mut x, &mut r, &mut rng, &mut draws, &mut deltas)
        }));
    }

    // --- atomic CAS residual update (threaded engine inner op) ---
    {
        let v = AtomicVec::from_slice(&vec![0.0; 4096]);
        let mut rng = Rng::new(8);
        results.push(bench_for("atomic fetch_add x64", 0.5, 64, || {
            for _ in 0..64 {
                v.fetch_add(rng.below(4096), 1e-9);
            }
        }));
    }

    // --- power iteration step ---
    {
        let ds = synth::sparse_imaging(2048, 4096, 0.01, 9);
        let mut v = vec![1.0 / (4096f64).sqrt(); 4096];
        let mut av = vec![0.0; 2048];
        let mut w = vec![0.0; 4096];
        results.push(bench_for("power_iter step (sparse 2048x4096)", 0.5, 32, || {
            ds.design.matvec(&v, &mut av);
            ds.design.matvec_t(&av, &mut w);
            let n = shotgun::sparsela::vecops::norm2(&w);
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / n.max(1e-30);
            }
        }));
    }

    // --- XLA block-round dispatch (when artifacts are built) ---
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use shotgun::runtime::XlaLassoEngine;
        use shotgun::solvers::common::SolveOptions;
        let mut engine = XlaLassoEngine::open(std::path::Path::new("artifacts"), "s").unwrap();
        let ds = synth::singlepix_pm1(256, 512, 10);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.3);
        let opts = SolveOptions {
            max_iters: 8, // one device call (k=8 fused rounds)
            tol: 0.0,
            ..Default::default()
        };
        results.push(bench_for("xla lasso_rounds call (k=8, s profile)", 2.0, 8, || {
            black_box(engine.solve_lasso(&prob, &vec![0.0; 512], &opts).unwrap())
        }));
    }

    println!("\n=== hotpath microbenchmarks ===");
    let mut json = String::new();
    for r in &results {
        println!("{}", r.report_line());
        json.push_str(&r.to_json());
        json.push('\n');
    }
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/hotpath.jsonl", json);
}
