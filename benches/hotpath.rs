//! Microbenchmarks of the L3 hot paths (criterion-substitute harness):
//! the per-update column kernels (plain + fused), one synchronous
//! Shotgun round, the end-to-end solve-to-tolerance path with the
//! coordinate scheduler on vs off, the pathwise orchestrator with
//! sequential strong rules on vs off, the threaded engine's CAS loop,
//! and the XLA block-round dispatch.
//!
//! `cargo bench --bench hotpath` (or `scripts/bench.sh`) — these are the
//! §Perf regression gates. Results go to stdout, to
//! `results/hotpath.jsonl`, and (machine-readable, tracked across PRs)
//! to `BENCH_hotpath.json`.

use shotgun::coordinator::atomic::AtomicVec;
use shotgun::coordinator::schedule::ShrinkConfig;
use shotgun::coordinator::{ShotgunConfig, ShotgunExact};
use shotgun::data::synth;
use shotgun::metrics::harness::{bench, bench_for, black_box, BenchResult};
use shotgun::objective::LassoProblem;
use shotgun::solvers::common::SolveOptions;
use shotgun::util::json::escape;
use shotgun::util::rng::Rng;

fn main() {
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor all artifacts at the workspace root so BENCH_hotpath.json
    // lands where the docs (and scripts/bench.sh) say it does
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .to_path_buf();
    // SHOTGUN_BENCH_SMOKE=1 (scripts/bench.sh --smoke, the CI
    // bench-smoke job): tiny problem sizes and second-scale budgets so
    // the whole harness — including every derived.* field the gate
    // checks — runs in seconds. Smoke numbers prove the plumbing, not
    // the perf; the real trajectory comes from the full run.
    let smoke = std::env::var("SHOTGUN_BENCH_SMOKE").ok().as_deref() == Some("1");
    if smoke {
        println!("(smoke mode: tiny sizes — CI plumbing check, not a perf measurement)");
    }
    let secs = |full: f64| if smoke { 0.05 } else { full };
    let mut results = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    // --- sparse column kernels (the per-update cost) ---
    {
        let (n, d) = if smoke { (512, 1024) } else { (4096, 8192) };
        let ds = synth::sparse_imaging(n, d, 0.01, 1);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
        let r = prob.residual(&vec![0.0; d]);
        let mut rng = Rng::new(2);
        results.push(bench_for(&format!("col_dot sparse (n={n})"), secs(0.5), 64, || {
            let j = rng.below(d);
            black_box(ds.design.col_dot(j, &r))
        }));
        let mut r2 = r.clone();
        let mut rng2 = Rng::new(3);
        results.push(bench_for("col_axpy sparse", secs(0.5), 64, || {
            let j = rng2.below(d);
            ds.design.col_axpy(j, 1e-9, &mut r2);
        }));
        // fused gather+scatter vs the two separate walks above
        let mut r3 = r.clone();
        let mut rng3 = Rng::new(4);
        results.push(bench_for("col_dot_axpy fused (gather+scatter)", secs(0.5), 64, || {
            let j = rng3.below(d);
            black_box(ds.design.col_dot_axpy(j, &mut r3, |g| 1e-12 * g))
        }));
    }

    // --- dense column kernels ---
    {
        let (n, d) = if smoke { (256, 512) } else { (1024, 2048) };
        let ds = synth::singlepix_pm1(n, d, 4);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.1);
        let r = prob.residual(&vec![0.0; d]);
        let mut rng = Rng::new(5);
        results.push(bench_for(&format!("col_dot dense (n={n})"), secs(0.5), 64, || {
            let j = rng.below(d);
            black_box(ds.design.col_dot(j, &r))
        }));
    }

    // --- one synchronous Shotgun round (P=8) ---
    {
        let (n, d) = if smoke { (256, 512) } else { (2048, 4096) };
        let ds = synth::sparse_imaging(n, d, 0.01, 6);
        let prob = LassoProblem::new(&ds.design, &ds.targets, 0.05);
        let engine = ShotgunExact::new(ShotgunConfig {
            p: 8,
            ..Default::default()
        });
        let mut x = vec![0.0; d];
        let mut r = prob.residual(&x);
        let mut rng = Rng::new(7);
        let mut draws = Vec::new();
        let mut deltas = Vec::new();
        results.push(bench_for(
            &format!("shotgun_round P=8 (sparse {n}x{d})"),
            secs(1.0),
            64,
            || engine.lasso_round(&prob, &mut x, &mut r, &mut rng, &mut draws, &mut deltas),
        ));
    }

    // --- solve-to-tolerance: the scheduler's end-to-end payoff ---
    // sparse_imaging 4096x8192, Shotgun exact P=8, identical options
    // except the shrink toggle. The objective gap is asserted hard; the
    // speedup-vs-1.5x acceptance gate is reported loudly and recorded
    // in BENCH_hotpath.json (not asserted, so noisy machines don't turn
    // a perf wobble into a red bench run).
    {
        let (n, d) = if smoke { (512, 1024) } else { (4096, 8192) };
        let ds = synth::sparse_imaging(n, d, 0.01, 1);
        let prob0 = LassoProblem::new(&ds.design, &ds.targets, 0.0);
        let lam = 0.2 * prob0.lambda_max();
        let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
        let opts_on = SolveOptions {
            max_iters: if smoke { 400_000 } else { 4_000_000 },
            tol: 1e-6,
            record_every: u64::MAX,
            seed: 11,
            ..Default::default()
        };
        let opts_off = SolveOptions {
            shrink: ShrinkConfig::disabled(),
            ..opts_on.clone()
        };
        let solve = |o: &SolveOptions| {
            ShotgunExact::new(ShotgunConfig {
                p: 8,
                ..Default::default()
            })
            .solve_lasso(&prob, &vec![0.0; d], o)
        };
        let f_on = solve(&opts_on);
        let f_off = solve(&opts_off);
        let gap = (f_on.objective - f_off.objective).abs() / f_off.objective.abs().max(1e-12);
        println!(
            "solve objectives: shrink-on F={:.8} ({} updates) shrink-off F={:.8} ({} updates), rel gap {:.2e}",
            f_on.objective, f_on.updates, f_off.objective, f_off.updates, gap
        );
        assert!(gap < 1e-3, "shrinking changed the optimum (gap {gap:.3e})");
        let samples = if smoke { 2 } else { 3 };
        let on = bench(
            &format!("lasso solve-to-tol shrink=on  (sparse {n}x{d})"),
            1,
            samples,
            || black_box(solve(&opts_on).objective),
        );
        let off = bench(
            &format!("lasso solve-to-tol shrink=off (sparse {n}x{d})"),
            1,
            samples,
            || black_box(solve(&opts_off).objective),
        );
        let speedup = off.median_s / on.median_s.max(1e-12);
        println!("scheduler speedup (solve-to-tol): {speedup:.2}x (gate: >= 1.5x)");
        if speedup < 1.5 {
            eprintln!(
                "WARNING: shrink speedup {speedup:.2}x is below the 1.5x acceptance gate"
            );
        }
        derived.push(("shrink_speedup_sparse_lasso".into(), speedup));
        derived.push(("shrink_speedup_gate".into(), 1.5));
        derived.push(("shrink_objective_rel_gap".into(), gap));
        results.push(on);
        results.push(off);
    }

    // --- pathwise orchestrator: sequential strong rules on vs off ---
    // same solver, same lambda path, same optima (asserted); the strong
    // rule screens the scheduler's starting set per stage. Ratio goes to
    // BENCH_hotpath.json as derived.path_strong_speedup.
    {
        use shotgun::solvers::path::{solve_path_lasso, PathConfig};
        let (n, d) = if smoke { (256, 512) } else { (2048, 4096) };
        let ds = synth::sparse_imaging(n, d, 0.01, 13);
        let prob0 = LassoProblem::new(&ds.design, &ds.targets, 0.0);
        let lam = 0.05 * prob0.lambda_max();
        let opts = SolveOptions {
            max_iters: if smoke { 400_000 } else { 4_000_000 },
            tol: 1e-6,
            record_every: u64::MAX,
            seed: 17,
            ..Default::default()
        };
        let run = |strong: bool| {
            let cfg = PathConfig {
                stages: 6,
                strong_rules: strong,
            };
            solve_path_lasso(&ds.design, &ds.targets, lam, &cfg, &opts, |p, x0, o| {
                ShotgunExact::new(ShotgunConfig {
                    p: 8,
                    ..Default::default()
                })
                .solve_lasso(p, x0, o)
            })
        };
        let f_on = run(true);
        let f_off = run(false);
        let gap = (f_on.objective - f_off.objective).abs() / f_off.objective.abs().max(1e-12);
        println!(
            "pathwise objectives: strong-on F={:.8} ({} updates) strong-off F={:.8} ({} updates), rel gap {:.2e}",
            f_on.objective, f_on.updates, f_off.objective, f_off.updates, gap
        );
        assert!(gap < 1e-3, "strong rules changed the optimum (gap {gap:.3e})");
        let samples = if smoke { 2 } else { 3 };
        let on = bench(
            &format!("lasso pathwise strong-rules=on  (sparse {n}x{d})"),
            1,
            samples,
            || black_box(run(true).objective),
        );
        let off = bench(
            &format!("lasso pathwise strong-rules=off (sparse {n}x{d})"),
            1,
            samples,
            || black_box(run(false).objective),
        );
        let speedup = off.median_s / on.median_s.max(1e-12);
        println!("strong-rules speedup (pathwise solve): {speedup:.2}x");
        derived.push(("path_strong_speedup".into(), speedup));
        derived.push(("path_strong_objective_rel_gap".into(), gap));
        results.push(on);
        results.push(off);
    }

    // --- portfolio racing vs Engine::Auto (solve-to-tolerance) ---
    // same problem, same tolerance: Auto commits to one engine/P from
    // the spectral estimate, the portfolio races the roster and takes
    // the first to converge. Wall-clock ratio goes to
    // derived.portfolio_vs_auto_speedup; per-label win counts over
    // repeated races go to derived.portfolio_win_rate_<label>.
    {
        use shotgun::api::{Engine, Fit};
        use shotgun::objective::ProblemCache;
        let (n, d) = if smoke { (256, 512) } else { (2048, 4096) };
        let ds = synth::sparse_imaging(n, d, 0.01, 21);
        let prob0 = LassoProblem::new(&ds.design, &ds.targets, 0.0);
        let lam = 0.2 * prob0.lambda_max();
        // shared cache: both engines reuse ONE memoized P* estimate, so
        // the comparison times the solves, not repeated power iterations
        let cache = ProblemCache::new(&ds.design);
        let fit = |engine: Engine| {
            Fit::new(&ds.design, &ds.targets)
                .lambda(lam)
                .engine(engine)
                .cache(&cache)
                .options(|o| {
                    o.max_iters = if smoke { 400_000 } else { 4_000_000 };
                    o.tol = 1e-6;
                    o.record_every = u64::MAX;
                    o.seed = 23;
                })
                .run()
                .expect("bench fit solves")
        };
        let r_auto = fit(Engine::Auto);
        let r_port = fit(Engine::Portfolio);
        let gap = (r_port.objective() - r_auto.objective()).abs()
            / r_auto.objective().abs().max(1e-12);
        println!(
            "portfolio F={:.8} ({}) vs auto F={:.8} ({}), rel gap {:.2e}",
            r_port.objective(),
            r_port.diagnostics.solver,
            r_auto.objective(),
            r_auto.diagnostics.solver,
            gap
        );
        assert!(gap < 1e-3, "portfolio winner missed the optimum (gap {gap:.3e})");
        // win-rate tally over repeated races (scheduling noise makes
        // the winner a distribution, not a constant)
        let races = if smoke { 2 } else { 5 };
        let mut wins: Vec<(String, usize)> = Vec::new();
        for _ in 0..races {
            let rep = fit(Engine::Portfolio);
            let w = rep.portfolio.expect("portfolio engine reports its race").winner;
            match wins.iter_mut().find(|(l, _)| *l == w) {
                Some((_, c)) => *c += 1,
                None => wins.push((w, 1)),
            }
        }
        for (label, c) in &wins {
            println!("portfolio winner {label}: {c}/{races} races");
        }
        let samples = if smoke { 2 } else { 3 };
        let auto_b = bench(
            &format!("lasso solve-to-tol engine=auto      (sparse {n}x{d})"),
            1,
            samples,
            || black_box(fit(Engine::Auto).objective()),
        );
        let port_b = bench(
            &format!("lasso solve-to-tol engine=portfolio (sparse {n}x{d})"),
            1,
            samples,
            || black_box(fit(Engine::Portfolio).objective()),
        );
        let speedup = auto_b.median_s / port_b.median_s.max(1e-12);
        println!("portfolio speedup over auto (solve-to-tol): {speedup:.2}x");
        derived.push(("portfolio_vs_auto_speedup".into(), speedup));
        derived.push(("portfolio_objective_rel_gap".into(), gap));
        for (label, c) in &wins {
            derived.push((
                format!("portfolio_win_rate_{label}"),
                *c as f64 / races as f64,
            ));
        }
        results.push(auto_b);
        results.push(port_b);
    }

    // --- atomic CAS residual update (threaded engine inner op) ---
    {
        let v = AtomicVec::from_slice(&vec![0.0; 4096]);
        let mut rng = Rng::new(8);
        results.push(bench_for("atomic fetch_add x64", secs(0.5), 64, || {
            for _ in 0..64 {
                v.fetch_add(rng.below(4096), 1e-9);
            }
        }));
    }

    // --- power iteration step ---
    {
        let (n, d) = if smoke { (256, 512) } else { (2048, 4096) };
        let ds = synth::sparse_imaging(n, d, 0.01, 9);
        let mut v = vec![1.0 / (d as f64).sqrt(); d];
        let mut av = vec![0.0; n];
        let mut w = vec![0.0; d];
        results.push(bench_for(
            &format!("power_iter step (sparse {n}x{d})"),
            secs(0.5),
            32,
            || {
                ds.design.matvec(&v, &mut av);
                ds.design.matvec_t(&av, &mut w);
                let nrm = shotgun::sparsela::vecops::norm2(&w);
                for (vi, wi) in v.iter_mut().zip(&w) {
                    *vi = wi / nrm.max(1e-30);
                }
            },
        ));
    }

    // --- CSC construction (counting-sort from_triplets) ---
    {
        use shotgun::sparsela::CscMatrix;
        let mut rng = Rng::new(10);
        let (n, d, per_col) = if smoke {
            (512usize, 1024usize, 10)
        } else {
            (4096usize, 8192usize, 40)
        };
        let mut trip = Vec::new();
        for j in 0..d {
            for _ in 0..per_col {
                trip.push((rng.below(n), j, rng.normal()));
            }
        }
        results.push(bench_for(
            &format!("from_triplets ({}k nnz)", d * per_col / 1000),
            secs(0.5),
            4,
            || black_box(CscMatrix::from_triplets(n, d, &trip).nnz()),
        ));
    }

    // --- XLA block-round dispatch (when artifacts are built) ---
    let artifacts = root.join("artifacts");
    if artifacts.join("manifest.json").exists() {
        use shotgun::runtime::XlaLassoEngine;
        match XlaLassoEngine::open(&artifacts, "s") {
            Ok(mut engine) => {
                let ds = synth::singlepix_pm1(256, 512, 10);
                let prob = LassoProblem::new(&ds.design, &ds.targets, 0.3);
                let opts = SolveOptions {
                    max_iters: 8, // one device call (k=8 fused rounds)
                    tol: 0.0,
                    ..Default::default()
                };
                results.push(bench_for("xla lasso_rounds call (k=8, s profile)", 2.0, 8, || {
                    black_box(engine.solve_lasso(&prob, &vec![0.0; 512], &opts).unwrap())
                }));
            }
            Err(e) => {
                println!("(artifacts present but device bench skipped: {e})");
            }
        }
    }

    println!("\n=== hotpath microbenchmarks ===");
    let mut jsonl = String::new();
    for r in &results {
        println!("{}", r.report_line());
        jsonl.push_str(&r.to_json());
        jsonl.push('\n');
    }
    let _ = std::fs::create_dir_all(root.join("results"));
    let _ = std::fs::write(root.join("results/hotpath.jsonl"), jsonl);

    // machine-readable perf trajectory, tracked across PRs
    let bench_json = root.join("BENCH_hotpath.json");
    let _ = std::fs::write(&bench_json, to_bench_json(&results, &derived));
    println!(
        "\nwrote {} ({} entries)",
        bench_json.display(),
        results.len()
    );
}

/// `BENCH_hotpath.json`: one object with per-bench (name, ns/op,
/// throughput) rows plus derived headline numbers.
fn to_bench_json(results: &[BenchResult], derived: &[(String, f64)]) -> String {
    let mut s = String::from("{\n  \"bench\": \"hotpath\",\n  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let ns = r.median_s * 1e9;
        let ops = if r.median_s > 0.0 { 1.0 / r.median_s } else { 0.0 };
        s.push_str(&format!(
            "    {{\"name\": {}, \"ns_per_op\": {:.1}, \"ops_per_s\": {:.3}, \"samples\": {}}}{}\n",
            escape(&r.name),
            ns,
            ops,
            r.samples,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"derived\": {\n");
    for (i, (k, v)) in derived.iter().enumerate() {
        // scientific notation: the rel-gap metric lives around 1e-6..1e-9
        // and fixed-point would flatten it to zero
        s.push_str(&format!(
            "    {}: {:.9e}{}\n",
            escape(k),
            v,
            if i + 1 < derived.len() { "," } else { "" }
        ));
    }
    s.push_str("  }\n}\n");
    s
}
