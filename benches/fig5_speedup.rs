//! Bench: regenerate Fig. 5 (self-speedup in iterations + simulated time),
//! plus the bound-validation table (E5) and headline numbers (E6/E7).
//! `cargo bench --bench fig5_speedup` — scale via SHOTGUN_BENCH_SCALE.

use shotgun::bench::{bounds, fig5, headline, BenchConfig};

fn main() {
    let cfg = BenchConfig {
        scale: std::env::var("SHOTGUN_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.15),
        ..Default::default()
    };
    fig5::run(&cfg);
    bounds::run(&cfg);
    headline::run(&cfg);
}
