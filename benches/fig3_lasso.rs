//! Bench: regenerate Fig. 3 (Lasso runtime scatter, solvers vs Shotgun P=8).
//! `cargo bench --bench fig3_lasso` — scale via SHOTGUN_BENCH_SCALE.

use shotgun::bench::{fig3, BenchConfig};

fn main() {
    let cfg = BenchConfig {
        scale: std::env::var("SHOTGUN_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0.12),
        max_seconds: 20.0,
        ..Default::default()
    };
    fig3::run(&cfg);
}
