//! Compressed-sensing image recovery — the workload motivating the
//! paper's Single-Pixel Camera and Sparse Compressed Imaging categories.
//!
//!   cargo run --release --example lasso_imaging
//!
//! Builds a synthetic "scene" with k-sparse structure, observes it
//! through two measurement matrices with very different spectral radii
//! (the Ball64 vs Mug32 phenomenon), and recovers with Shotgun — showing
//! how P* governs usable parallelism on each.

use shotgun::coordinator::{PStar, ShotgunConfig, ShotgunExact};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::solvers::common::SolveOptions;
use shotgun::sparsela::vecops;

fn recover(name: &str, ds: &shotgun::data::Dataset, lam_frac: f64) {
    let d = ds.d();
    let est = PStar::quick(&ds.design, 7);
    println!("\n== {name}: n={}, d={d}, rho={:.2}, P*={}", ds.n(), est.rho, est.p_star);

    let lam = lam_frac * LassoProblem::new(&ds.design, &ds.targets, 0.0).lambda_max();
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let x_true = ds.x_true.as_ref().expect("synthetic truth");

    for p in [1usize, est.p_star.clamp(1, 64), (4 * est.p_star).clamp(2, 256)] {
        let opts = SolveOptions {
            max_iters: 4_000_000 / p as u64,
            tol: 1e-8,
            record_every: (d as u64 / p as u64).max(1),
            ..Default::default()
        };
        let res = ShotgunExact::new(ShotgunConfig {
            p,
            ..Default::default()
        })
        .solve_lasso(&prob, &vec![0.0; d], &opts);
        // recovery quality: relative L2 error against the true scene
        let err: f64 = res
            .x
            .iter()
            .zip(x_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
            / vecops::norm2(x_true).max(1e-12);
        let status = if res.solver.ends_with("diverged") {
            "DIVERGED"
        } else if res.converged {
            "converged"
        } else {
            "budget"
        };
        println!(
            "  P={p:<4} rounds={:<8} F={:<12.6} rel-err={err:.3} [{status}]",
            res.iters, res.objective
        );
    }
}

fn main() {
    println!("Compressed-sensing recovery with Shotgun (Fig. 2's two regimes)");
    // Mug32-like: ±1 Rademacher measurements -> decorrelated, high P*
    let mug = synth::singlepix_pm1(410, 1024, 11);
    recover("Mug32-like (±1 measurements)", &mug, 0.05);
    // Ball64-like: 0/1 Bernoulli measurements -> rho ~ d/2, P* ~ 3
    let ball = synth::singlepix_binary(410, 1024, 13);
    recover("Ball64-like (0/1 measurements)", &ball, 0.5);
    println!("\nNote how the 0/1 matrix tolerates far less parallelism — exactly");
    println!("the paper's Fig. 2: P* is a property of the data, not the machine.");
}
