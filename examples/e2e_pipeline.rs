//! END-TO-END driver: exercises every layer of the system on a real
//! small workload, proving they compose (the EXPERIMENTS.md §E2E run).
//!
//!   make artifacts && cargo run --release --example e2e_pipeline
//!
//! Pipeline:
//!   1. generate a compressed-imaging dataset (substrate: data/sparsela)
//!   2. estimate P* two ways — rust power iteration AND the AOT
//!      `power_iter` graph through PJRT (L1 Pallas + L2 JAX + runtime)
//!   3. solve the Lasso three ways and cross-check objectives:
//!        a. Shotgun exact engine (L3, theory-faithful)
//!        b. Shotgun threaded engine (L3, atomic CAS, the paper's impl)
//!        c. Shotgun XLA engine (device block rounds via Pallas kernels)
//!   4. pathwise-continuation run (the practical Fig. 3 configuration)
//!   5. report the headline iteration-speedup and the memory-wall
//!      simulated time-speedup

use shotgun::coordinator::{Engine, PStar, Shotgun, ShotgunConfig};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::runtime::XlaLassoEngine;
use shotgun::simcore::CostModel;
use shotgun::solvers::common::{LassoSolver, SolveOptions};
use shotgun::solvers::path::solve_pathwise;
use std::path::Path;

fn main() {
    println!("=== Shotgun end-to-end pipeline ===\n");
    // --- 1. workload ---
    let n = 256;
    let d = 512;
    let ds = synth::sparse_imaging(n, d, 0.05, 2026);
    println!(
        "[1] dataset {}: n={n}, d={d}, {:.1}% nonzero",
        ds.name,
        100.0 * ds.design.density()
    );
    let prob0 = LassoProblem::new(&ds.design, &ds.targets, 0.0);
    let lam_max = prob0.lambda_max();
    let lam = 0.1 * lam_max;
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);

    // --- 2. P* both ways ---
    let est = PStar::quick(&ds.design, 3);
    println!(
        "[2] rust power iteration: rho={:.4} P*={} ({:.3}s)",
        est.rho, est.p_star, est.seconds
    );
    let artifacts = Path::new("artifacts");
    let mut xla_engine = if artifacts.join("manifest.json").exists() {
        match XlaLassoEngine::open(artifacts, "m") {
            Ok(mut e) => {
                let rho_dev = e.power_iter_rho(&prob).expect("device rho");
                println!(
                    "    device power_iter (L1 Pallas via PJRT): rho={rho_dev:.4} (Δ={:.2e})",
                    (rho_dev - est.rho).abs()
                );
                Some(e)
            }
            Err(e) => {
                println!("    (xla engine unavailable: {e})");
                None
            }
        }
    } else {
        println!("    (artifacts/ not built; run `make artifacts` for the device path)");
        None
    };

    // --- 3. three engines, one optimum ---
    let p = est.clamp(8);
    let opts = SolveOptions {
        max_iters: 2_000_000,
        tol: 1e-7,
        record_every: (d as u64 / p as u64).max(1),
        seed: 7,
        ..Default::default()
    };
    let exact = Shotgun::new(ShotgunConfig {
        p,
        ..Default::default()
    })
    .solve_lasso(&prob, &vec![0.0; d], &opts);
    println!(
        "[3a] exact engine:    F={:.6} rounds={} ({:.3}s)",
        exact.objective, exact.iters, exact.seconds
    );
    let threaded = Shotgun::new(ShotgunConfig {
        p,
        engine: Engine::Threaded,
        ..Default::default()
    })
    .solve_lasso(&prob, &vec![0.0; d], &opts);
    println!(
        "[3b] threaded engine: F={:.6} updates={} ({:.3}s)",
        threaded.objective, threaded.updates, threaded.seconds
    );
    assert!(
        (exact.objective - threaded.objective).abs() / exact.objective < 1e-2,
        "engines disagree"
    );
    if let Some(engine) = xla_engine.as_mut() {
        let dev = engine
            .solve_lasso(&prob, &vec![0.0; d], &opts)
            .expect("device solve");
        println!(
            "[3c] xla engine:      F={:.6} device-rounds={} ({:.3}s)",
            dev.objective, dev.iters, dev.seconds
        );
        assert!(
            (exact.objective - dev.objective).abs() / exact.objective < 5e-2,
            "device engine disagrees"
        );
    }

    // --- 4. pathwise (practical configuration) ---
    let path = solve_pathwise(lam_max, lam, 5, d, &opts, |l, x0, o| {
        let p_ = LassoProblem::new(&ds.design, &ds.targets, l);
        Shotgun::new(ShotgunConfig {
            p,
            ..Default::default()
        })
        .solve_lasso(&p_, x0, o)
    });
    println!(
        "[4] pathwise ({}): F={:.6} total-updates={}",
        path.solver, path.objective, path.updates
    );

    // --- 5. headline numbers ---
    let seq = Shotgun::with_p(1).solve_lasso(&prob, &vec![0.0; d], &opts);
    let iter_speedup = seq.iters as f64 / exact.iters.max(1) as f64;
    let model = CostModel::default();
    let avg_nnz = ds.design.nnz() as f64 / d as f64;
    let t1 = model.async_seconds(seq.updates, avg_nnz, 1);
    let tp = model.async_seconds(exact.updates, avg_nnz, p);
    println!(
        "[5] P={p}: iteration speedup {:.1}x; memory-wall simulated time speedup {:.1}x",
        iter_speedup,
        t1 / tp
    );
    println!("    (paper: ~P x iterations, 2-4x time at P=8 — the memory wall)");
    println!("\nE2E PIPELINE OK");
}
