//! Quickstart: solve a Lasso with Shotgun and inspect the result.
//!
//!   cargo run --release --example quickstart
//!
//! Walks the core API: generate data, estimate P* from the spectral
//! radius (Theorem 3.2), solve with Shotgun at that P, verify optimality.

use shotgun::coordinator::{PStar, Shotgun, ShotgunConfig};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::solvers::common::{LassoSolver, SolveOptions};

fn main() {
    // 1. a sparse compressed-imaging style problem (d = 2n, ±1 entries)
    let ds = synth::sparse_imaging(512, 1024, 0.02, 42);
    println!(
        "dataset: {} (n={}, d={}, {:.1}% nonzero)",
        ds.name,
        ds.n(),
        ds.d(),
        100.0 * ds.design.density()
    );

    // 2. how parallel can coordinate descent go on this data?
    //    Theorem 3.2: P* = ceil(d / rho(A^T A)); rho via power iteration
    let est = PStar::quick(&ds.design, 1);
    println!(
        "rho(A^T A) = {:.3} -> P* = {} (estimated in {:.3}s)",
        est.rho, est.p_star, est.seconds
    );

    // 3. solve the Lasso with Shotgun at P = min(8, P*)
    let p = est.clamp(8);
    let lam = 0.1;
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let mut solver = Shotgun::new(ShotgunConfig {
        p,
        ..Default::default()
    });
    let opts = SolveOptions {
        max_iters: 2_000_000,
        tol: 1e-8,
        record_every: 512,
        ..Default::default()
    };
    let res = solver.solve_lasso(&prob, &vec![0.0; ds.d()], &opts);
    println!(
        "{}: F = {:.6}, {} nonzeros, {} rounds ({} updates) in {:.3}s",
        res.solver,
        res.objective,
        res.nnz(),
        res.iters,
        res.updates,
        res.seconds
    );

    // 4. certify: KKT violation at the solution should be ~0
    let r = prob.residual(&res.x);
    println!("KKT violation: {:.2e}", prob.kkt_violation(&res.x, &r));

    // 5. compare with sequential Shooting (P = 1) on iterations
    let mut sequential = Shotgun::with_p(1);
    let seq = sequential.solve_lasso(&prob, &vec![0.0; ds.d()], &opts);
    println!(
        "Shooting (P=1): {} rounds; Shotgun (P={p}): {} rounds -> {:.1}x fewer",
        seq.iters,
        res.iters,
        seq.iters as f64 / res.iters.max(1) as f64
    );
}
