//! Quickstart: solve a Lasso through the `api::Fit` front door.
//!
//!   cargo run --release --example quickstart
//!
//! Walks the core API: generate data, let `Engine::Auto` estimate P*
//! from the spectral radius (Theorem 3.2) and pick the engine, inspect
//! the servable model, verify optimality, and compare against the
//! sequential baseline by name.

use shotgun::api::{Engine, Fit, SolverParams};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;

fn main() {
    // 1. a sparse compressed-imaging style problem (d = 2n, ±1 entries)
    let ds = synth::sparse_imaging(512, 1024, 0.02, 42);
    println!(
        "dataset: {} (n={}, d={}, {:.1}% nonzero)",
        ds.name,
        ds.n(),
        ds.d(),
        100.0 * ds.design.density()
    );

    // 2. solve with Engine::Auto — Theorem 3.2 (P* = ceil(d/rho), rho by
    //    power iteration) picks the parallelism, so there is no P knob
    //    to mis-set
    let lam = 0.1;
    let report = Fit::new(&ds.design, &ds.targets)
        .lambda(lam)
        .engine(Engine::Auto)
        .options(|o| {
            o.max_iters = 2_000_000;
            o.tol = 1e-8;
            o.record_every = 512;
        })
        .run()
        .expect("validated inputs solve");
    let auto = report.auto.as_ref().expect("auto reports its choice");
    println!(
        "rho(A^T A) = {:.3} -> P* = {}, running {} at P = {}",
        auto.rho,
        auto.p_star,
        if auto.threaded { "threaded" } else { "exact" },
        auto.p
    );
    let res = &report.diagnostics;
    println!(
        "{}: F = {:.6}, {} nonzeros, {} rounds ({} updates) in {:.3}s",
        res.solver,
        res.objective,
        report.model.nnz(),
        res.iters,
        res.updates,
        res.seconds
    );

    // 3. certify: KKT violation at the solution should be ~0
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let r = prob.residual(&res.x);
    println!("KKT violation: {:.2e}", prob.kkt_violation(&res.x, &r));

    // 4. compare with sequential Shotgun (P = 1) on iterations, picked
    //    from the same registry by name
    let seq = Fit::new(&ds.design, &ds.targets)
        .lambda(lam)
        .solver("shotgun")
        .params(SolverParams {
            p: 1,
            ..Default::default()
        })
        .options(|o| {
            o.max_iters = 2_000_000;
            o.tol = 1e-8;
            o.record_every = 512;
        })
        .run()
        .expect("sequential baseline solves");
    println!(
        "Shotgun P=1: {} rounds; auto (P={}): {} rounds -> {:.1}x fewer",
        seq.diagnostics.iters,
        auto.p,
        res.iters,
        seq.diagnostics.iters as f64 / res.iters.max(1) as f64
    );

    // 5. the fit is a servable artifact: JSON out, JSON in, same model
    let restored = shotgun::api::Model::from_json(&report.model.to_json()).expect("roundtrip");
    assert_eq!(restored, report.model);
    println!(
        "model JSON round-trip OK ({} stored weights)",
        restored.weights().len()
    );
}
