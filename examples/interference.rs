//! Fig. 1 intuition, made quantitative (E8): parallel updates help when
//! features are uncorrelated and fight when they are correlated.
//!
//!   cargo run --release --example interference
//!
//! Measures Theorem 3.1's decomposition directly: for one synchronous
//! Shotgun round, F(x + Δx) - F(x) splits into a sequential-progress term
//! -1/2 Σ δ_j² and an interference term 1/2 Σ_{j≠k} (A^T A)_{jk} δ_j δ_k.

use shotgun::coordinator::{ShotgunConfig, ShotgunExact};
use shotgun::data::synth;
use shotgun::objective::LassoProblem;
use shotgun::util::rng::Rng;

/// One exact round; returns (actual ΔF, progress term, interference term).
fn round_decomposition(ds: &shotgun::data::Dataset, lam: f64, p: usize, seed: u64) -> (f64, f64, f64) {
    let prob = LassoProblem::new(&ds.design, &ds.targets, lam);
    let d = ds.d();
    // start from a few sequential steps so deltas are non-trivial
    let mut x = vec![0.0; d];
    let mut r = prob.residual(&x);
    let mut rng = Rng::new(seed);
    for _ in 0..d {
        let j = rng.below(d);
        let dx = prob.cd_step(j, x[j], &r);
        prob.apply_step(j, dx, &mut x, &mut r);
    }
    let f_before = prob.objective_from_residual(&r, &x);

    // one synchronous round of P updates
    let engine = ShotgunExact::new(ShotgunConfig {
        p,
        ..Default::default()
    });
    let mut draws = Vec::new();
    let mut deltas = Vec::new();
    let mut x2 = x.clone();
    let mut r2 = r.clone();
    engine.lasso_round(&prob, &mut x2, &mut r2, &mut rng, &mut draws, &mut deltas);
    let f_after = prob.objective_from_residual(&r2, &x2);

    // Theorem 3.1 terms
    let progress: f64 = -0.5 * deltas.iter().map(|d| d * d).sum::<f64>();
    let mut interference = 0.0;
    let dense = ds.design.to_dense();
    for (a, (&ja, &da)) in draws.iter().zip(&deltas).enumerate().map(|(i, jd)| (i, jd)) {
        for (b, (&jb, &db)) in draws.iter().zip(&deltas).enumerate().map(|(i, jd)| (i, jd)) {
            if a != b {
                let gram: f64 = (0..ds.n()).map(|i| dense.get(i, ja) * dense.get(i, jb)).sum();
                interference += 0.5 * gram * da * db;
            }
        }
    }
    (f_after - f_before, progress, interference)
}

fn main() {
    println!("Theorem 3.1: ΔF <= progress + interference, one Shotgun round (P=8)\n");
    println!(
        "{:<28} {:>12} {:>12} {:>14} {:>8}",
        "design", "ΔF", "progress", "interference", "bound?"
    );
    for (name, c) in [
        ("uncorrelated (c=0.0)", 0.0),
        ("mild (c=0.3)", 0.3),
        ("correlated (c=0.8)", 0.8),
        ("near-duplicate (c=0.97)", 0.97),
    ] {
        let ds = synth::correlated(256, 64, c, 5);
        let (df, prog, intf) = round_decomposition(&ds, 0.05, 8, 9);
        let holds = df <= prog + intf + 1e-9;
        println!(
            "{name:<28} {df:>12.6} {prog:>12.6} {intf:>14.6} {holds:>8}"
        );
    }
    println!("\nUncorrelated: interference ~ 0 and the full progress lands.");
    println!("Correlated: positive interference eats the progress — the Fig. 1");
    println!("right-hand panel, and the reason Theorem 3.2 caps P at d/rho.");
    println!("\n(Caveat: Theorem 3.1 is proven in the non-negative duplicated-");
    println!("feature space; our signed-coordinate measurement can slightly");
    println!("violate the decomposition when a step crosses zero, as the");
    println!("near-duplicate row sometimes shows at ~1e-4 magnitudes.)");
}
