//! Serving repeated fits — the ROADMAP's "heavy traffic on one design"
//! scenario, end to end through the `api` front door:
//!
//!   cargo run --release --example serving
//!
//! 1. load one design, build its `ProblemCache` ONCE (the O(nnz)
//!    metadata pass);
//! 2. serve a stream of fit requests (different lambdas/losses) through
//!    `Fit`, each reusing the cache — per-request setup is an Arc bump;
//! 3. ship the winning model as JSON, reload it in a "scorer" that
//!    never sees the training stack, and verify predictions match
//!    bit-for-bit.

use shotgun::api::{Fit, Model, PathSpec};
use shotgun::data::synth;
use shotgun::objective::ProblemCache;

fn main() {
    // --- load time: one design, one metadata pass ---
    let ds = synth::sparse_imaging(512, 1024, 0.02, 2026);
    let cache = ProblemCache::new(&ds.design);
    println!(
        "design loaded: n={}, d={}, {:.1}% nonzero; ProblemCache built once",
        ds.n(),
        ds.d(),
        100.0 * ds.design.density()
    );

    // --- request stream: fits at several regularization strengths ---
    let mut models = Vec::new();
    for lam in [0.8, 0.4, 0.2, 0.1] {
        let report = Fit::new(&ds.design, &ds.targets)
            .lambda(lam)
            .solver("shotgun")
            .p(8)
            .cache(&cache) // no per-request O(nnz) pass
            .options(|o| {
                o.max_iters = 2_000_000;
                o.tol = 1e-7;
            })
            .run()
            .expect("validated request");
        println!(
            "  lam={lam:<4} -> F = {:.6}, nnz = {:>4}, {} updates, {:.3}s",
            report.objective(),
            report.model.nnz(),
            report.diagnostics.updates,
            report.diagnostics.seconds
        );
        models.push(report.model);
    }

    // a pathwise fit amortizes even further: one request, whole path
    let path_report = Fit::new(&ds.design, &ds.targets)
        .path(PathSpec::to(0.1))
        .solver("shotgun")
        .p(8)
        .cache(&cache)
        .options(|o| o.max_iters = 2_000_000)
        .run()
        .expect("pathwise request");
    println!(
        "pathwise to lam=0.1: {} ({} updates total)",
        path_report.diagnostics.solver, path_report.diagnostics.updates
    );

    // --- ship the artifact ---
    let chosen = models.last().expect("served at least one fit");
    let doc = chosen.to_json();
    println!("shipping model: {} bytes of JSON", doc.len());

    // --- scorer process: reload and serve ---
    let scorer = Model::from_json(&doc).expect("artifact parses");
    let before = chosen.predict(&ds.design).expect("predict");
    let after = scorer.predict(&ds.design).expect("predict");
    let identical = before
        .iter()
        .zip(&after)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "reloaded model predictions bit-identical: {identical} (provenance: solver={}, lam={})",
        scorer.solver, scorer.lam
    );
    assert!(identical);
}
