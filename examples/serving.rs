//! Serving end to end — the ROADMAP's "heavy traffic on one design"
//! scenario through the `api::serve` subsystem:
//!
//!   cargo run --release --example serving
//!
//! 1. a bounded [`FitQueue`] drains fit jobs (several lambdas on one
//!    shared design — the `ProblemCache` is built once by the queue's
//!    cache hub) and publishes each winner into a [`ModelStore`];
//! 2. a [`BatchPredictor`] coalesces a seeded request stream into
//!    `Design`-batched predict calls against the store — bit-identical
//!    to one-at-a-time `Model::predict`, but the walk over the model's
//!    weights is paid once per batch;
//! 3. a hot-swap publishes a refit under the same name: in-flight
//!    batches finish on the version they started with, the next batch
//!    serves the new one;
//! 4. the store persists as JSON and a fresh "scorer" process reloads
//!    it, predictions surviving bit-for-bit.

use shotgun::api::serve::{BatchConfig, BatchPredictor, FitJob, FitQueue, JobState, ModelStore};
use shotgun::data::synth;
use shotgun::objective::Loss;
use shotgun::testkit::requests::{stream, StreamSpec};
use std::sync::Arc;

fn main() {
    // --- load time: one design, shared by every job via Arc ---
    let ds = synth::sparse_imaging(512, 1024, 0.02, 2026);
    println!(
        "design loaded: n={}, d={}, {:.1}% nonzero",
        ds.n(),
        ds.d(),
        100.0 * ds.design.density()
    );
    let design = Arc::new(ds.design);
    let targets = Arc::new(ds.targets);

    // --- fit side: queue jobs at several lambdas, publish the winner ---
    let store = Arc::new(ModelStore::new());
    let queue = FitQueue::with_store(2, 8, Arc::clone(&store)).expect("valid queue params");
    let lambdas = [0.8, 0.4, 0.2, 0.1];
    let ids: Vec<_> = lambdas
        .iter()
        .map(|&lam| {
            let job = FitJob::new(
                Arc::clone(&design),
                Arc::clone(&targets),
                Loss::Squared,
                lam,
            )
            .solver_name("shotgun")
            .options(|o| {
                o.max_iters = 2_000_000;
                o.tol = 1e-7;
            })
            // each finished fit hot-swaps the served model
            .publish_as("default");
            queue.submit(job).expect("queue accepts while open")
        })
        .collect();
    for (lam, id) in lambdas.iter().zip(ids) {
        match queue.wait(id).expect("submitted job") {
            JobState::Done(report) => println!(
                "  lam={lam:<4} -> F = {:.6}, nnz = {:>4}, {} updates ({})",
                report.objective(),
                report.model.nnz(),
                report.diagnostics.updates,
                report.diagnostics.solver
            ),
            JobState::Failed(e) => panic!("fit job failed: {e}"),
            other => unreachable!("{other:?}"),
        }
    }
    // one design -> the queue's cache hub built exactly one ProblemCache
    assert_eq!(queue.cache_hub().len(), 1);
    let serving = store.get("default").expect("published");
    println!(
        "serving \"default\" v{} (solver {}, lam {})",
        serving.version, serving.model.solver, serving.model.lam
    );

    // --- serve side: coalesced batches over a seeded request stream ---
    const MAX_BATCH: usize = 64;
    let requests = stream(&StreamSpec::new(1024, 256), 7);
    let mut predictor = BatchPredictor::new(
        Arc::clone(&store),
        "default",
        BatchConfig {
            max_batch: MAX_BATCH,
            ..Default::default()
        },
    );
    let responses = predictor.run(&requests).expect("well-formed stream");
    println!(
        "served {} requests in {} coalesced batches (versions all = {})",
        responses.len(),
        (requests.len() + MAX_BATCH - 1) / MAX_BATCH,
        responses[0].model_version
    );
    assert!(responses
        .iter()
        .all(|r| r.model_version == serving.version));

    // --- ship the store, reload in a scorer process ---
    let dir = std::env::temp_dir().join("shotgun_serving_example");
    store.save_dir(&dir).expect("persist store");
    let scorer_store = Arc::new(ModelStore::new());
    scorer_store.load_dir(&dir).expect("reload store");
    let mut scorer = BatchPredictor::new(scorer_store, "default", BatchConfig::default());
    let replayed = scorer.run(&requests).expect("same stream");
    let identical = responses
        .iter()
        .zip(&replayed)
        .all(|(a, b)| a.prediction.to_bits() == b.prediction.to_bits());
    println!("reloaded store predictions bit-identical: {identical}");
    assert!(identical);
    let _ = std::fs::remove_dir_all(&dir);
}
