//! Sparse logistic regression on text-like data — the paper's §4.2
//! workload (rcv1: d > n, 17% dense). Compares Shotgun CDN against the
//! SGD family on training objective and held-out error.
//!
//!   cargo run --release --example logreg_text

use shotgun::coordinator::ShotgunCdn;
use shotgun::data::synth;
use shotgun::objective::LogisticProblem;
use shotgun::solvers::cdn::ShootingCdn;
use shotgun::solvers::common::{LogisticSolver, SolveOptions};
use shotgun::solvers::parallel_sgd::ParallelSgd;
use shotgun::solvers::sgd::{Rate, Sgd};

fn main() {
    // rcv1-like regime: more features than samples, sparse counts
    let ds = synth::rcv1_like(728, 1780, 0.17, 21);
    let (train, test) = ds.split_holdout(10);
    println!(
        "dataset {}: train n={}, test n={}, d={}, density={:.2}",
        ds.name,
        train.n(),
        test.n(),
        ds.d(),
        ds.design.density()
    );
    let lam = 0.01;
    let prob = LogisticProblem::new(&train.design, &train.targets, lam);
    let test_prob = LogisticProblem::new(&test.design, &test.targets, lam);
    let d = train.d();
    let x0 = vec![0.0; d];

    let opts = SolveOptions {
        max_iters: 60,
        record_every: 4,
        tol: 1e-8,
        seed: 3,
        ..Default::default()
    };
    let cd_opts = SolveOptions {
        max_iters: 60_000,
        record_every: (d as u64 / 4).max(1),
        ..opts.clone()
    };

    println!(
        "\n{:<18} {:>12} {:>12} {:>10} {:>10}",
        "solver", "train-F", "test-err", "updates", "time"
    );
    let show = |name: &str, res: shotgun::solvers::common::SolveResult| {
        println!(
            "{:<18} {:>12.4} {:>11.2}% {:>10} {:>9.3}s",
            name,
            res.objective,
            100.0 * test_prob.error_rate(&res.x),
            res.updates,
            res.seconds
        );
    };

    show(
        "shotgun-cdn-p8",
        ShotgunCdn::with_p(8).solve_logistic(&prob, &x0, &cd_opts),
    );
    show(
        "shooting-cdn",
        ShootingCdn::default().solve_logistic(&prob, &x0, &opts),
    );
    // paper protocol: sweep constant rates, keep the best
    let sweep_opts = SolveOptions {
        max_iters: 3,
        ..opts.clone()
    };
    let (eta, _) = Sgd::sweep(&prob, &x0, &sweep_opts, 1e-4, 1.0, 7);
    println!("  (sgd rate sweep chose eta = {eta:.4})");
    show(
        "sgd",
        Sgd::new(Rate::Constant(eta)).solve_logistic(&prob, &x0, &opts),
    );
    show(
        "parallel-sgd-p8",
        ParallelSgd::new(8, Rate::Constant(eta)).solve_logistic(&prob, &x0, &opts),
    );
    println!(
        "\nPaper shape (Fig. 4, rcv1): Shotgun CDN converges much faster than"
    );
    println!("SGD in the d > n regime; Parallel SGD tracks SGD almost exactly.");
}
