#!/usr/bin/env python3
"""Generate the golden convergence fixtures under rust/tests/fixtures/.

Each fixture is a small dense problem (column-normalized design,
seeded) solved to near machine precision by an independent reference
implementation of cyclic coordinate descent, written here in
numpy — NOT by any solver in the Rust crate. The fixture records the
data, lambda, the reference optimum x_star, and the optimal objective
f_star; `rust/tests/golden_fixtures.rs` then asserts every registered
exact-optimum solver reaches f_star within its documented tolerance.
Because f_star comes from outside the crate, a silent convergence (or
objective-convention) regression cannot re-bake itself into the
fixtures.

Objective conventions (must match rust/src/objective/):
  squared:  F(x) = 0.5 * ||Ax - y||^2 + lam * ||x||_1
  logistic: F(x) = sum_i log(1 + exp(-y_i * a_i.x)) + lam * ||x||_1
  sqhinge:  F(x) = 0.5 * sum_i max(0, 1 - y_i * a_i.x)^2 + lam * ||x||_1
  huber:    F(x) = sum_i H_delta(a_i.x - y_i) + lam * ||x||_1, delta = 1
            (H_delta(r) = r^2/2 inside |r| <= delta, delta*|r| - delta^2/2 beyond)

Run from the repo root:  python3 scripts/make_fixtures.py

The CI fixtures job reruns this script and fails on drift against the
committed rust/tests/fixtures/*.json, so regeneration must be
byte-stable (seeded numpy default_rng only).
"""

import json
import os

import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")


def soft(z, t):
    return np.sign(z) * max(abs(z) - t, 0.0)


def solve_lasso_cd(A, y, lam, sweeps=400_000, tol=1e-15):
    """Cyclic CD with exact per-coordinate minimization."""
    n, d = A.shape
    col_sq = (A * A).sum(axis=0)
    x = np.zeros(d)
    r = A @ x - y
    for _ in range(sweeps):
        max_dx = 0.0
        for j in range(d):
            if col_sq[j] == 0.0:
                continue
            g = A[:, j] @ r
            z = x[j] - g / col_sq[j]
            xj_new = soft(z, lam / col_sq[j])
            dx = xj_new - x[j]
            if dx != 0.0:
                r += dx * A[:, j]
                x[j] = xj_new
            max_dx = max(max_dx, abs(dx))
        if max_dx < tol:
            break
    return x


def lasso_objective(A, y, lam, x):
    r = A @ x - y
    return 0.5 * float(r @ r) + lam * float(np.abs(x).sum())


def solve_logistic_cd(A, y, lam, sweeps=400_000, tol=1e-14):
    """Cyclic CD with the paper's beta = 1/4 Lipschitz step (monotone)."""
    n, d = A.shape
    col_sq = (A * A).sum(axis=0)
    x = np.zeros(d)
    z = A @ x  # margins a_i . x
    for _ in range(sweeps):
        max_dx = 0.0
        for j in range(d):
            if col_sq[j] == 0.0:
                continue
            m = y * z
            sig = 1.0 / (1.0 + np.exp(m))  # sigma(-m), stable for m >= 0...
            # ...use the numerically symmetric form for both signs:
            sig = np.where(m >= 0, np.exp(-m) / (1.0 + np.exp(-m)), 1.0 / (1.0 + np.exp(m)))
            g = -float((y * A[:, j] * sig).sum())
            h = 0.25 * col_sq[j]
            xj_new = soft(x[j] - g / h, lam / h)
            dx = xj_new - x[j]
            if dx != 0.0:
                z += dx * A[:, j]
                x[j] = xj_new
            max_dx = max(max_dx, abs(dx))
        if max_dx < tol:
            break
    return x


def logistic_objective(A, y, lam, x):
    m = y * (A @ x)
    # log(1 + exp(-m)), stable
    loss = np.logaddexp(0.0, -m).sum()
    return float(loss) + lam * float(np.abs(x).sum())


def solve_sqhinge_cd(A, y, lam, sweeps=400_000, tol=1e-15):
    """Cyclic CD with the beta = 1 Lipschitz step (1/2-convention squared
    hinge: the active-set second derivative is exactly 1, so the step is
    monotone)."""
    n, d = A.shape
    col_sq = (A * A).sum(axis=0)
    x = np.zeros(d)
    z = A @ x
    for _ in range(sweeps):
        max_dx = 0.0
        for j in range(d):
            if col_sq[j] == 0.0:
                continue
            slack = np.maximum(0.0, 1.0 - y * z)
            g = -float((y * A[:, j] * slack).sum())
            h = col_sq[j]
            xj_new = soft(x[j] - g / h, lam / h)
            dx = xj_new - x[j]
            if dx != 0.0:
                z += dx * A[:, j]
                x[j] = xj_new
            max_dx = max(max_dx, abs(dx))
        if max_dx < tol:
            break
    return x


def sqhinge_objective(A, y, lam, x):
    slack = np.maximum(0.0, 1.0 - y * (A @ x))
    return 0.5 * float((slack * slack).sum()) + lam * float(np.abs(x).sum())


HUBER_DELTA = 1.0


def solve_huber_cd(A, y, lam, sweeps=400_000, tol=1e-15, delta=HUBER_DELTA):
    """Cyclic CD with the beta = 1 Lipschitz step (H'' <= 1)."""
    n, d = A.shape
    col_sq = (A * A).sum(axis=0)
    x = np.zeros(d)
    r = A @ x - y
    for _ in range(sweeps):
        max_dx = 0.0
        for j in range(d):
            if col_sq[j] == 0.0:
                continue
            w = np.clip(r, -delta, delta)
            g = float((A[:, j] * w).sum())
            h = col_sq[j]
            xj_new = soft(x[j] - g / h, lam / h)
            dx = xj_new - x[j]
            if dx != 0.0:
                r += dx * A[:, j]
                x[j] = xj_new
            max_dx = max(max_dx, abs(dx))
        if max_dx < tol:
            break
    return x


def huber_objective(A, y, lam, x, delta=HUBER_DELTA):
    r = A @ x - y
    a = np.abs(r)
    h = np.where(a <= delta, 0.5 * r * r, delta * (a - 0.5 * delta))
    return float(h.sum()) + lam * float(np.abs(x).sum())


def normalized_design(rng, n, d):
    A = rng.standard_normal((n, d))
    A /= np.linalg.norm(A, axis=0)
    return A


def kkt_violation(A, y, lam, x, loss):
    """Max KKT violation at x — the committed optimality proof for every
    fixture (a CD bug in this script would otherwise bake a wrong f_star
    into the Rust gate)."""
    if loss == "squared":
        g = A.T @ (A @ x - y)
    elif loss == "logistic":
        m = y * (A @ x)
        sig = np.where(m >= 0, np.exp(-m) / (1.0 + np.exp(-m)), 1.0 / (1.0 + np.exp(m)))
        g = -(A.T @ (y * sig))
    elif loss == "sqhinge":
        slack = np.maximum(0.0, 1.0 - y * (A @ x))
        g = -(A.T @ (y * slack))
    elif loss == "huber":
        g = A.T @ np.clip(A @ x - y, -HUBER_DELTA, HUBER_DELTA)
    else:
        raise ValueError(f"unknown loss {loss!r}")
    viol = 0.0
    for j in range(len(x)):
        if abs(x[j]) > 1e-12:
            viol = max(viol, abs(g[j] + lam * np.sign(x[j])))
        else:
            viol = max(viol, max(0.0, abs(g[j]) - lam))
    return viol


def fixture(name, loss, n, d, seed, lam_frac):
    rng = np.random.default_rng(seed)
    A = normalized_design(rng, n, d)
    k = max(1, d // 4)
    x_true = np.zeros(d)
    support = rng.choice(d, size=k, replace=False)
    x_true[support] = rng.standard_normal(k) * 2.0

    if loss == "squared":
        y = A @ x_true + 0.1 * rng.standard_normal(n)
        lam = lam_frac * float(np.abs(A.T @ y).max())  # fraction of lambda_max
        x_star = solve_lasso_cd(A, y, lam)
        f_star = lasso_objective(A, y, lam, x_star)
    elif loss == "logistic":
        y = np.sign(A @ x_true + 0.2 * rng.standard_normal(n))
        y[y == 0] = 1.0
        # lambda_max for logistic: max |A^T grad| at x = 0 (grad_i = -y_i/2)
        lam = lam_frac * float(np.abs(A.T @ (0.5 * y)).max())
        x_star = solve_logistic_cd(A, y, lam)
        f_star = logistic_objective(A, y, lam, x_star)
    elif loss == "sqhinge":
        y = np.sign(A @ x_true + 0.2 * rng.standard_normal(n))
        y[y == 0] = 1.0
        # lambda_max for sqhinge: at x = 0 every slack is 1, g = -A^T y
        lam = lam_frac * float(np.abs(A.T @ y).max())
        x_star = solve_sqhinge_cd(A, y, lam)
        f_star = sqhinge_objective(A, y, lam, x_star)
    elif loss == "huber":
        y = A @ x_true + 0.1 * rng.standard_normal(n)
        # gross outliers so the linear branch of the loss is exercised
        # at the optimum (otherwise the fixture would just re-test the
        # squared loss)
        outliers = rng.choice(n, size=max(1, n // 6), replace=False)
        y[outliers] += 20.0 * np.sign(rng.standard_normal(len(outliers)) + 0.25)
        # lambda_max for huber: r = -y at x = 0, g = A^T clip(-y, ±delta)
        lam = lam_frac * float(np.abs(A.T @ np.clip(-y, -HUBER_DELTA, HUBER_DELTA)).max())
        x_star = solve_huber_cd(A, y, lam)
        f_star = huber_objective(A, y, lam, x_star)
    else:
        raise ValueError(f"unknown loss {loss!r}")

    nnz = int((np.abs(x_star) > 1e-10).sum())
    assert 0 < nnz < d, f"{name}: degenerate optimum (nnz = {nnz})"
    viol = kkt_violation(A, y, lam, x_star, loss)
    assert viol < 1e-12, f"{name}: x_star is not optimal (KKT violation {viol:.3e})"
    doc = {
        "format": "shotgun.fixture.v1",
        "name": name,
        "loss": loss,
        "n": n,
        "d": d,
        "seed": seed,
        # column-major to match DenseMatrix::from_col_major
        "col_major": [float(v) for v in A.flatten(order="F")],
        "targets": [float(v) for v in y],
        "lam": lam,
        "x_star": [float(v) for v in x_star],
        "f_star": f_star,
        "nnz_star": nnz,
    }
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as fh:
        json.dump(doc, fh, separators=(",", ":"))
        fh.write("\n")
    print(
        f"{name}: n={n} d={d} lam={lam:.6g} f_star={f_star:.12g} "
        f"nnz={nnz} kkt_violation={viol:.3e}"
    )


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    fixture("lasso_small", "squared", 12, 8, seed=1, lam_frac=0.2)
    fixture("lasso_wide", "squared", 8, 16, seed=2, lam_frac=0.3)
    fixture("logistic_small", "logistic", 16, 6, seed=3, lam_frac=0.2)
    fixture("logistic_wide", "logistic", 10, 12, seed=4, lam_frac=0.3)
    fixture("sqhinge_small", "sqhinge", 16, 6, seed=5, lam_frac=0.2)
    fixture("sqhinge_wide", "sqhinge", 10, 12, seed=6, lam_frac=0.3)
    fixture("huber_small", "huber", 12, 8, seed=7, lam_frac=0.2)
    fixture("huber_wide", "huber", 8, 16, seed=8, lam_frac=0.3)


if __name__ == "__main__":
    main()
