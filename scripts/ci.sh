#!/usr/bin/env bash
# CI gate: formatting, lints, docs, and the tier-1 verify command.
#
#   scripts/ci.sh          run everything
#   scripts/ci.sh fast     skip the release build (fmt + clippy + tests)
#
# Mirrors .github/workflows/ci.yml so the gate is reproducible locally.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "scripts/ci.sh: cargo not found on PATH." >&2
  echo "Install the toolchain pinned in rust-toolchain.toml, e.g.:" >&2
  echo "  curl https://sh.rustup.rs -sSf | sh -s -- -y && rustup show" >&2
  exit 127
fi

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (deny warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

echo "== cargo doc (deny warnings + broken intra-doc links) =="
RUSTDOCFLAGS="-D warnings -D rustdoc::broken-intra-doc-links" \
  cargo doc --no-deps --workspace

echo "== feature-gated xla surface (stub + integration tests) =="
cargo check --features xla --all-targets

if [[ "${1:-}" != "fast" ]]; then
  echo "== tier-1: cargo build --release =="
  cargo build --release
fi

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== serving subsystem: end-to-end harness + golden fixtures =="
# also covered by `cargo test -q` above; run named so a serving
# regression is visible as its own CI step
cargo test -q --test serving --test golden_fixtures --test registry_capabilities \
  --test model_edge_cases --test beyond_losses

echo "== sim-scenarios: deterministic traffic & fault simulator =="
# run-to-run and cross-worker-count Outcome equality for the named
# scenario suite (incl. the multi-tenant quartet: multi-model-routing,
# shard-swap-under-load, priority-inversion, overload-shedding, and the
# PR-10 QoS scenarios: flooding-tenant A/B, edf-beats-fifo,
# dropped-ticket-no-work, hot-shard-rebalance), fault semantics, and
# the workload-generator laws
cargo test -q --test simserve

echo "== doctests: cargo test --doc =="
cargo test --doc -q

echo "CI gate passed."
