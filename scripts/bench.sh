#!/usr/bin/env bash
# Run the hot-path microbenchmarks and refresh BENCH_hotpath.json (the
# machine-readable perf trajectory tracked across PRs). Includes the
# pathwise strong-rules on/off comparison (derived.path_strong_speedup
# and derived.path_strong_objective_rel_gap).
#
# Usage: scripts/bench.sh [extra cargo bench args]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench hotpath "$@"
echo
echo "--- BENCH_hotpath.json ---"
cat BENCH_hotpath.json
