#!/usr/bin/env bash
# Run the hot-path microbenchmarks and refresh BENCH_hotpath.json (the
# machine-readable perf trajectory tracked across PRs). Includes the
# pathwise strong-rules on/off comparison (derived.path_strong_speedup
# and derived.path_strong_objective_rel_gap). Then replay the serving
# benchmark (`repro serve`) and refresh BENCH_serving.json (throughput
# + latency percentiles of the batching predictor).
#
# Usage: scripts/bench.sh [extra cargo bench args]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench hotpath "$@"
echo
echo "--- BENCH_hotpath.json ---"
cat BENCH_hotpath.json

echo
echo "== serving replay (BENCH_serving.json) =="
cargo run --release --bin repro -- serve \
  --data imaging:2048x4096:0.005 --lam 0.1 --solver shotgun \
  --requests 20000 --max-batch 64 --max-wait-us 2000 --clients 8 \
  --bench-out BENCH_serving.json
echo
echo "--- BENCH_serving.json ---"
cat BENCH_serving.json
