#!/usr/bin/env bash
# Run the hot-path microbenchmarks and refresh BENCH_hotpath.json (the
# machine-readable perf trajectory tracked across PRs). Includes the
# pathwise strong-rules on/off comparison (derived.path_strong_speedup
# and derived.path_strong_objective_rel_gap). Then replay the serving
# benchmark (`repro serve --compare-unbatched`) and refresh
# BENCH_serving.json (throughput + latency percentiles of the batching
# predictor, plus derived.batching_speedup_throughput from the
# max_batch=1 baseline replay). Then run the kernel A/B harness
# (`repro bench kernels`) and refresh BENCH_kernels.json
# (derived.simd_speedup, derived.shard_vs_atomic_speedup,
# derived.clustered_vs_uniform_epochs). Finally run the deterministic
# serving simulator (`repro sim`) and refresh BENCH_simserve.json
# (derived.batching_latency_p99_ratio, derived.fault_recovery_rounds,
# derived.swap_visibility_lag_us, plus the QoS quartet:
# derived.fairness_p99_ratio, derived.edf_deadline_hit_rate,
# derived.cancelled_flush_rows, derived.rebalance_p99_gain — all on
# virtual time, so identical across machines and runs).
#
# Usage:
#   scripts/bench.sh [extra cargo bench args]   full run (perf numbers)
#   scripts/bench.sh --smoke                    tiny sizes, seconds not
#                                               minutes — the CI
#                                               bench-smoke job; numbers
#                                               prove the plumbing, not
#                                               the perf
#
# Both modes finish by validating that every derived.* field in the two
# BENCH json files is present and finite (scripts/check_bench.py).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "scripts/bench.sh: cargo not found on PATH." >&2
  echo "Install the toolchain pinned in rust-toolchain.toml (e.g. via rustup) and re-run." >&2
  exit 127
fi

SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
  SMOKE=1
  shift
fi

if [[ "$SMOKE" == "1" ]]; then
  export SHOTGUN_BENCH_SMOKE=1
  # smoke replays under deficit round-robin so the DRR flush path gets
  # a real-threaded CLI exercise too (the sim suite A/Bs it on virtual
  # time); the full run keeps the first-seen default
  SERVE_ARGS=(--data imaging:256x512:0.02 --lam 0.1 --solver shotgun
    --requests 2000 --max-batch 32 --max-wait-us 500 --clients 4
    --models 4 --shards 4 --fairness drr:8)
  echo "== bench.sh --smoke: tiny sizes, CI plumbing check =="
else
  SERVE_ARGS=(--data imaging:2048x4096:0.005 --lam 0.1 --solver shotgun
    --requests 20000 --max-batch 64 --max-wait-us 2000 --clients 8
    --models 4 --shards 4)
fi

cargo bench --bench hotpath "$@"
echo
echo "--- BENCH_hotpath.json ---"
cat BENCH_hotpath.json

echo
echo "== portfolio racing engine (CLI path) =="
# exercise the portfolio end to end through the CLI: the race must pick
# a winner, cancel the losers, and print the report (the hotpath bench
# above already gates derived.portfolio_vs_auto_speedup and the
# win-rate fields; this proves the --solver portfolio plumbing)
if [[ "$SMOKE" == "1" ]]; then
  cargo run --release --bin repro -- solve --data imaging:256x512:0.02 \
    --lam 0.1 --solver portfolio --tol 1e-6 --max-iters 200000
else
  cargo run --release --bin repro -- solve --data imaging:2048x4096:0.005 \
    --lam 0.1 --solver portfolio --tol 1e-6
fi

echo
echo "== serving replay (BENCH_serving.json) =="
cargo run --release --bin repro -- serve "${SERVE_ARGS[@]}" \
  --compare-unbatched --bench-out BENCH_serving.json
echo
echo "--- BENCH_serving.json ---"
cat BENCH_serving.json

echo
echo "== kernel A/B harness (BENCH_kernels.json) =="
# compiled with the simd feature so the dispatched side of the A/B is
# the AVX2 path wherever the host supports it (runtime-detected; on
# non-AVX2 hosts both sides run the scalar kernels and the ratio ~1.0)
cargo run --release -p shotgun --features simd --bin repro -- bench kernels
echo
echo "--- BENCH_kernels.json ---"
cat BENCH_kernels.json

echo
echo "== serving simulator (BENCH_simserve.json) =="
# virtual-time scenario suite: smoke mode is picked up automatically via
# SHOTGUN_BENCH_SMOKE=1 exported above; the full run stretches horizons
# 10x and rates 2.5x. Either way the emitted numbers are deterministic
# functions of the seed.
cargo run --release --bin repro -- sim --seed 42 --bench-out BENCH_simserve.json
echo
echo "--- BENCH_simserve.json ---"
cat BENCH_simserve.json

echo
echo "== derived-field gate (scripts/check_bench.py) =="
python3 scripts/check_bench.py BENCH_hotpath.json BENCH_serving.json BENCH_kernels.json BENCH_simserve.json
