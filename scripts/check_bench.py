#!/usr/bin/env python3
"""Validate the machine-readable bench artifacts.

The EXPERIMENTS.md §Perf tables are fed by derived.* fields in
BENCH_hotpath.json, BENCH_serving.json, BENCH_kernels.json, and
BENCH_simserve.json. This gate fails CI (the bench-smoke job, and the
tail of scripts/bench.sh) when any required derived field is missing,
non-numeric, NaN, or non-positive — i.e. when the harness silently
stopped producing the numbers the tables track.

Usage: python3 scripts/check_bench.py BENCH_hotpath.json BENCH_serving.json BENCH_kernels.json BENCH_simserve.json
"""

import json
import math
import sys

# per-file required derived fields (speedups must be finite AND > 0;
# the *_gap fields only need to be finite numbers)
REQUIRED = {
    "hotpath": {
        "positive": ["shrink_speedup_sparse_lasso", "path_strong_speedup",
                     "portfolio_vs_auto_speedup"],
        "finite": ["shrink_objective_rel_gap", "path_strong_objective_rel_gap",
                   "portfolio_objective_rel_gap"],
        # the portfolio win-rate keys are label-suffixed (the winning
        # config varies run to run), so the spec requires AT LEAST ONE
        # key per prefix, each finite and > 0
        "positive_prefix": ["portfolio_win_rate_"],
    },
    "serving": {
        # multi_model_routing_overhead is the single-tenant/multi-tenant
        # throughput ratio (PR-9 router); shard_swap_stall_us is the worst
        # publish stall observed while readers hammer other shards — 0 is a
        # legitimate value on a fast box, so it only needs to be finite.
        "positive": ["batching_speedup_throughput", "batching_unbatched_rps",
                     "multi_model_routing_overhead"],
        "finite": ["shard_swap_stall_us"],
    },
    # the PR-6 hot-path A/Bs: simd dispatch vs scalar, sharded vs
    # atomic accumulation, clustered vs uniform draws. All three are
    # ratios, so "present, finite, > 0" is the invariant — near 1.0 is
    # a legitimate value (e.g. simd feature off), 0/NaN means the
    # harness broke.
    "kernels": {
        "positive": [
            "simd_speedup",
            "shard_vs_atomic_speedup",
            "clustered_vs_uniform_epochs",
        ],
        "finite": ["shard_objective_rel_gap", "schedule_objective_rel_gap"],
    },
    # the PR-8 deterministic serving simulator (`repro sim`): virtual-
    # latency cost of deeper batching, worker-panic recovery measured in
    # batch rounds, hot-swap visibility lag. All virtual-time, so the
    # values are machine-independent; 0/NaN means the simulator stopped
    # measuring, not that the machine was fast.
    "simserve": {
        # PR-9 adds overload_shed_requests (typed Overloaded rejections in
        # the overload-shedding scenario — the scenario is tuned so sheds
        # always happen, hence > 0) and priority_queue_lead_jobs (batch
        # fillers still pending when the High job finished; > 0 proves the
        # priority lanes actually reorder work).
        # PR-10 adds the QoS quartet: fairness_p99_ratio (the flooding
        # scenario's victim p99 under FirstSeen over DeficitRr),
        # edf_deadline_hit_rate (fraction of dated burst jobs completed
        # inside their deadlines — the suite is built so EDF hits 1.0),
        # cancelled_flush_rows (rows skipped at flush after their ticket
        # was dropped — the scenario drops 3, so > 0), and
        # rebalance_p99_gain (hot shard's read share before/after the
        # rebalance re-homes names).
        "positive": ["batching_latency_p99_ratio", "fault_recovery_rounds",
                     "overload_shed_requests", "priority_queue_lead_jobs",
                     "fairness_p99_ratio", "edf_deadline_hit_rate",
                     "cancelled_flush_rows", "rebalance_p99_gain"],
        "finite": ["swap_visibility_lag_us"],
    },
}


def check(path):
    errors = []
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    bench = doc.get("bench")
    spec = REQUIRED.get(bench)
    if spec is None:
        return [f"{path}: unknown bench tag {bench!r}"]
    derived = doc.get("derived")
    if not isinstance(derived, dict):
        return [f"{path}: missing derived section"]
    for key in spec["positive"] + spec["finite"]:
        v = derived.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            errors.append(f"{path}: derived.{key} missing or non-numeric (got {v!r})")
            continue
        if math.isnan(v) or math.isinf(v):
            errors.append(f"{path}: derived.{key} is not finite ({v})")
        elif key in spec["positive"] and v <= 0.0:
            errors.append(f"{path}: derived.{key} must be > 0 (got {v})")
    for prefix in spec.get("positive_prefix", []):
        matched = [k for k in derived if k.startswith(prefix)]
        if not matched:
            errors.append(f"{path}: no derived.{prefix}* field (harness emitted none)")
        for key in matched:
            v = derived[key]
            if (
                not isinstance(v, (int, float))
                or isinstance(v, bool)
                or math.isnan(v)
                or math.isinf(v)
                or v <= 0.0
            ):
                errors.append(f"{path}: derived.{key} must be finite and > 0 (got {v!r})")
    # every other derived field must at least be a finite number
    for key, v in derived.items():
        if key in spec["positive"] or key in spec["finite"]:
            continue
        if not isinstance(v, (int, float)) or math.isnan(v) or math.isinf(v):
            errors.append(f"{path}: derived.{key} is not a finite number ({v!r})")
    return errors


def main():
    paths = sys.argv[1:]
    if not paths:
        print(__doc__)
        return 2
    errors = []
    for path in paths:
        errors.extend(check(path))
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"ok: {len(paths)} bench artifact(s), all derived fields finite")
    return 0


if __name__ == "__main__":
    sys.exit(main())
