"""AOT path structure tests: the HLO artifacts the rust runtime consumes.

These are perf regression gates as much as correctness checks: the round
body must contain exactly the two matmuls of the block update (gradient
+ residual apply) with no recomputation, and every entrypoint must lower
through the HLO-text interchange.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

PROF = dict(n=32, d=48, p=4, k=3, power_steps=4)


def lower_text(name):
    for entry, fn, eargs in aot.entries(PROF):
        if entry == name:
            return aot.to_hlo_text(jax.jit(fn).lower(*eargs))
    raise KeyError(name)


def test_lasso_rounds_has_exactly_two_dots():
    """One A_S^T r and one A_S @ delta per round — no gradient recompute
    between the delta and the residual update (EXPERIMENTS.md §Perf L2)."""
    text = lower_text("lasso_rounds")
    assert text.count("dot(") == 2, f"expected 2 dots, got {text.count('dot(')}"
    assert "while" in text, "K rounds must lower to a fused while loop"


def test_all_entrypoints_lower():
    for entry, fn, eargs in aot.entries(PROF):
        text = aot.to_hlo_text(jax.jit(fn).lower(*eargs))
        assert text.startswith("HloModule"), entry
        # 64-bit-id proto regression guard: text must parse as ASCII HLO
        assert "ENTRY" in text, entry


def test_manifest_matches_artifacts_on_disk():
    import json
    import os

    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(adir, "manifest.json")
    if not os.path.exists(mpath):
        return  # artifacts not built in this checkout
    with open(mpath) as f:
        manifest = json.load(f)
    for art in manifest["artifacts"]:
        path = os.path.join(adir, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule"), art["file"]
    # every entry x profile present
    entries = {(a["entry"], a["profile"]) for a in manifest["artifacts"]}
    for tag in manifest["profiles"]:
        for name in [
            "lasso_round",
            "lasso_rounds",
            "lasso_objective",
            "logistic_round",
            "logistic_objective",
            "power_iter",
        ]:
            assert (name, tag) in entries, (name, tag)


def test_padded_problem_is_exact():
    """Zero-padding rows/columns (the rust runtime's profile fit) must not
    change the round's effect on the real coordinates."""
    rng = np.random.default_rng(0)
    n, d, big_n, big_d = 12, 10, 20, 16
    A = rng.normal(size=(n, d)).astype(np.float32)
    A /= np.linalg.norm(A, axis=0, keepdims=True)
    A_pad = np.zeros((big_n, big_d), dtype=np.float32)
    A_pad[:n, :d] = A
    r = rng.normal(size=n).astype(np.float32)
    r_pad = np.zeros(big_n, dtype=np.float32)
    r_pad[:n] = r
    x = rng.normal(size=d).astype(np.float32)
    x_pad = np.zeros(big_d, dtype=np.float32)
    x_pad[:d] = x
    idx = rng.integers(0, d, size=4).astype(np.int32)
    lam = 0.3

    r1, x1 = model.lasso_round(jnp.array(A), jnp.array(r), jnp.array(x), jnp.array(idx), lam)
    r2, x2 = model.lasso_round(
        jnp.array(A_pad), jnp.array(r_pad), jnp.array(x_pad), jnp.array(idx), lam
    )
    np.testing.assert_allclose(r2[:n], r1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(x2[:d], x1, rtol=1e-5, atol=1e-6)
    # padding stays exactly zero
    np.testing.assert_array_equal(np.asarray(r2[n:]), 0.0)
    np.testing.assert_array_equal(np.asarray(x2[d:]), 0.0)
