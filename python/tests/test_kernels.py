"""Pallas kernels vs pure-jnp oracles: the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/seeds; fixed cases pin the paper's
semantics (duplicate draws, zero residual, saturating shrinkage).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import shotgun as K

jax.config.update("jax_enable_x64", False)

shapes = st.tuples(
    st.integers(min_value=1, max_value=96),   # n
    st.integers(min_value=1, max_value=48),   # d
    st.integers(min_value=1, max_value=12),   # p
)


def make_problem(n, d, p, seed):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, d)).astype(np.float32)
    # normalize columns (paper assumes diag(A^T A) = 1)
    A /= np.maximum(np.linalg.norm(A, axis=0, keepdims=True), 1e-6)
    r = rng.normal(size=n).astype(np.float32)
    x = (rng.normal(size=d) * rng.binomial(1, 0.3, size=d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    idx = rng.integers(0, d, size=p).astype(np.int32)  # multiset: dups allowed
    return jnp.array(A), jnp.array(r), jnp.array(x), jnp.array(y), jnp.array(idx)


@settings(max_examples=40, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1), st.floats(0.01, 10.0))
def test_shotgun_block_update_matches_ref(shape, seed, lam):
    n, d, p = shape
    A, r, x, _, idx = make_problem(n, d, p, seed)
    beta = 1.0
    d_k, r_k, x_k = K.shotgun_block_update(A, r, x, idx, lam, beta)
    d_r, r_r, x_r = ref.shotgun_block_update_ref(A, r, x, idx, lam, beta)
    np.testing.assert_allclose(d_k, d_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r_k, r_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(x_k, x_r, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_block_grad_matches_ref(shape, seed):
    n, d, p = shape
    A, r, _, _, idx = make_problem(n, d, p, seed)
    g_k = K.block_grad(A[:, idx], r)
    g_r = (A[:, idx]).T @ r
    np.testing.assert_allclose(g_k, g_r, rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_matvec_matches_ref(shape, seed):
    n, d, p = shape
    A, _, x, _, _ = make_problem(n, d, p, seed)
    np.testing.assert_allclose(
        K.matvec(A, x[: A.shape[1]]), ref.matvec_ref(A, x), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=30, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_logistic_probs_matches_ref(shape, seed):
    n, d, p = shape
    A, _, x, y, _ = make_problem(n, d, p, seed)
    np.testing.assert_allclose(
        K.logistic_probs(A, x, y), ref.logistic_probs_ref(A, x, y),
        rtol=1e-5, atol=1e-6,
    )


@settings(max_examples=30, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_logistic_block_grad_matches_ref(shape, seed):
    n, d, p = shape
    A, _, x, y, idx = make_problem(n, d, p, seed)
    np.testing.assert_allclose(
        K.logistic_block_grad(A, x, y, idx),
        ref.logistic_block_grad_ref(A, x, y, idx),
        rtol=1e-4, atol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 48), st.integers(1, 32),
    st.integers(0, 2**31 - 1), st.floats(0.0, 5.0), st.floats(0.05, 4.0),
)
def test_soft_threshold_matches_ref(d, p, seed, lam, beta):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=p).astype(np.float32))
    g = jnp.array(rng.normal(size=p).astype(np.float32))
    np.testing.assert_allclose(
        K.soft_threshold_block(x, g, lam, beta),
        ref.soft_threshold_update(x, g, lam, beta),
        rtol=1e-5, atol=1e-6,
    )


def test_duplicate_draws_sum_deltas():
    """Alg. 2 multiset semantics: a coordinate drawn twice gets both deltas."""
    n, d = 16, 8
    A, r, x, _, _ = make_problem(n, d, 1, 7)
    idx = jnp.array([3, 3, 5, 3], dtype=jnp.int32)
    d_k, r_k, x_k = K.shotgun_block_update(A, r, x, idx, 0.1, 1.0)
    d_r, r_r, x_r = ref.shotgun_block_update_ref(A, r, x, idx, 0.1, 1.0)
    np.testing.assert_allclose(x_k, x_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(r_k, r_r, rtol=1e-5, atol=1e-6)
    # scatter-add really added all three deltas for coordinate 3
    np.testing.assert_allclose(
        x_k[3] - x[3], d_k[0] + d_k[1] + d_k[3], rtol=1e-5, atol=1e-6
    )


def test_zero_residual_zero_gradient_shrinks_only():
    """With r = 0 the update reduces to pure shrinkage toward 0."""
    n, d, p = 32, 16, 4
    A, _, x, _, idx = make_problem(n, d, p, 11)
    r = jnp.zeros(n)
    lam, beta = 0.5, 1.0
    delta, _, _ = K.shotgun_block_update(A, r, x, idx, lam, beta)
    u = x[idx]
    expected = jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam, 0.0) - u
    np.testing.assert_allclose(delta, expected, rtol=1e-5, atol=1e-6)


def test_large_lambda_drives_block_to_zero():
    n, d, p = 32, 16, 6
    A, r, x, _, _ = make_problem(n, d, p, 13)
    # unique draws: with duplicates, two -x_j deltas overshoot past zero
    # (the multiset semantics Thm 3.2's conflict analysis accounts for)
    idx = jnp.array([0, 3, 5, 7, 11, 15], dtype=jnp.int32)
    _, _, x_new = K.shotgun_block_update(A, r, x, idx, 1e6, 1.0)
    np.testing.assert_allclose(x_new[np.asarray(idx)], 0.0, atol=1e-6)


@pytest.mark.parametrize("tile", [1, 8, 64, 256, 1000])
def test_tile_size_invariance(tile):
    """Any tile_n (dividing or not) gives identical numerics."""
    n, d, p = 64, 24, 8
    A, r, x, _, idx = make_problem(n, d, p, 5)
    base = K.block_grad(A[:, idx], r, tile_n=64)
    np.testing.assert_allclose(
        K.block_grad(A[:, idx], r, tile_n=tile), base, rtol=1e-4, atol=1e-5
    )


def test_power_iter_step_matches_ref():
    n, d = 48, 24
    A, _, _, _, _ = make_problem(n, d, 1, 3)
    v = jnp.ones(d) / np.sqrt(d)
    v_k, n_k = K.power_iter_step(A, v)
    v_r, n_r = ref.power_iter_step_ref(A, v)
    np.testing.assert_allclose(v_k, v_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n_k, n_r, rtol=1e-4)


def test_power_iteration_converges_to_rho():
    """rho estimate converges to the true spectral radius of A^T A."""
    rng = np.random.default_rng(0)
    A = rng.normal(size=(40, 20)).astype(np.float32)
    A /= np.linalg.norm(A, axis=0, keepdims=True)
    v = jnp.ones(20) / np.sqrt(20)
    nrm = 0.0
    for _ in range(200):
        v, nrm = K.power_iter_step(A, v)
    true_rho = np.max(np.linalg.eigvalsh(A.T @ A))
    np.testing.assert_allclose(float(nrm), true_rho, rtol=1e-3)
