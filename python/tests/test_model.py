"""L2 model graphs: shapes, semantics, and descent properties."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def make_lasso(n=64, d=32, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, d)).astype(np.float32)
    A /= np.linalg.norm(A, axis=0, keepdims=True)
    x_true = (rng.normal(size=d) * rng.binomial(1, 0.2, size=d)).astype(np.float32)
    y = (A @ x_true + 0.01 * rng.normal(size=n)).astype(np.float32)
    return jnp.array(A), jnp.array(y)


def test_lasso_round_decreases_objective_small_p():
    A, y = make_lasso()
    n, d = A.shape
    lam = 0.1
    x = jnp.zeros(d)
    r = -y  # Ax - y with x = 0
    rng = np.random.default_rng(1)
    f_prev = float(model.lasso_objective(A, x, y, lam))
    for _ in range(30):
        idx = jnp.array(rng.integers(0, d, size=2), dtype=jnp.int32)
        r, x = model.lasso_round(A, r, x, idx, lam)
        f = float(model.lasso_objective(A, x, y, lam))
        assert f <= f_prev + 1e-4, "P=2 << P* rounds must descend"
        f_prev = f


def test_lasso_rounds_matches_sequential_rounds():
    A, y = make_lasso(48, 24, 2)
    d = A.shape[1]
    lam = 0.2
    x0 = jnp.zeros(d)
    r0 = -y
    rng = np.random.default_rng(3)
    idxs = jnp.array(rng.integers(0, d, size=(10, 4)), dtype=jnp.int32)
    r_scan, x_scan = model.lasso_rounds(A, r0, x0, idxs, lam)
    r_seq, x_seq = r0, x0
    for k in range(10):
        r_seq, x_seq = model.lasso_round(A, r_seq, x_seq, idxs[k], lam)
    np.testing.assert_allclose(x_scan, x_seq, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(r_scan, r_seq, rtol=1e-4, atol=1e-5)


def test_residual_consistency_after_rounds():
    """Carried residual must equal Ax - y exactly (the Ax-cache invariant)."""
    A, y = make_lasso(40, 20, 4)
    d = A.shape[1]
    x, r = jnp.zeros(d), -y
    rng = np.random.default_rng(5)
    for _ in range(20):
        idx = jnp.array(rng.integers(0, d, size=4), dtype=jnp.int32)
        r, x = model.lasso_round(A, r, x, idx, 0.05)
    np.testing.assert_allclose(r, A @ x - y, rtol=1e-4, atol=1e-4)


def test_lasso_objective_matches_ref():
    A, y = make_lasso(32, 16, 6)
    x = jnp.array(np.random.default_rng(7).normal(size=16).astype(np.float32))
    np.testing.assert_allclose(
        model.lasso_objective(A, x, y, 0.3),
        ref.lasso_objective_ref(A, x, y, 0.3),
        rtol=1e-5,
    )


def test_logistic_objective_matches_ref():
    A, _ = make_lasso(32, 16, 8)
    rng = np.random.default_rng(9)
    y = jnp.array(rng.choice([-1.0, 1.0], size=32).astype(np.float32))
    x = jnp.array(rng.normal(size=16).astype(np.float32))
    np.testing.assert_allclose(
        model.logistic_objective(A, x, y, 0.3),
        ref.logistic_objective_ref(A, x, y, 0.3),
        rtol=1e-5,
    )


def test_logistic_round_descends():
    A, _ = make_lasso(64, 24, 10)
    rng = np.random.default_rng(11)
    y = jnp.array(rng.choice([-1.0, 1.0], size=64).astype(np.float32))
    x = jnp.zeros(24)
    lam = 0.05
    f_prev = float(model.logistic_objective(A, x, y, lam))
    for _ in range(25):
        idx = jnp.array(rng.integers(0, 24, size=2), dtype=jnp.int32)
        x = model.logistic_round(A, x, y, idx, lam)
        f = float(model.logistic_objective(A, x, y, lam))
        assert f <= f_prev + 1e-4
        f_prev = f


def test_power_iter_estimates_rho():
    A, _ = make_lasso(48, 24, 12)
    v = jnp.ones(24) / np.sqrt(24)
    _, rho = model.power_iter(A, v, 300)
    true_rho = float(np.max(np.linalg.eigvalsh(np.asarray(A).T @ np.asarray(A))))
    np.testing.assert_allclose(float(rho), true_rho, rtol=1e-3)


def test_entrypoints_lower_to_hlo_text():
    """Every AOT entrypoint must lower through the stablehlo->HLO-text path
    (the exact interchange the rust runtime consumes)."""
    from compile import aot

    prof = dict(n=16, d=24, p=4, k=3, power_steps=4)
    for name, fn, eargs in aot.entries(prof):
        lowered = jax.jit(fn).lower(*eargs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
