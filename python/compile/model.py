"""Layer-2 JAX compute graphs for the Shotgun system.

Each public function here is an AOT entrypoint: `aot.py` jits + lowers it
to HLO text for the rust runtime (`rust/src/runtime/`). The flops inside
route through the Layer-1 Pallas kernels (kernels/shotgun.py) so they lower
into the same HLO module. Python never runs on the request path.

Entry points (shapes fixed at AOT time, see aot.py manifest):
  lasso_round        one synchronous Shotgun round on the dense Lasso
  lasso_rounds       K fused rounds via lax.scan (dispatch amortization)
  logistic_round     one Shotgun round on sparse logistic regression
  lasso_objective    F(x) for convergence monitoring
  logistic_objective F(x) for convergence monitoring
  power_iter         K power-iteration steps estimating rho(A^T A)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import shotgun as K

LOGISTIC_BETA = 0.25  # Assumption 2.1 for the logistic loss (paper Eq. 6)
LASSO_BETA = 1.0      # squared loss


def lasso_round(A, r, x, idx, lam):
    """One Shotgun round for the Lasso. r = Ax - y is carried by the caller.

    Returns (r_new, x_new). The coordinate block `idx` is sampled by the
    rust coordinator (it owns the RNG and the multiset semantics).
    """
    _, r_new, x_new = K.shotgun_block_update(A, r, x, idx, lam, LASSO_BETA)
    return r_new, x_new


def lasso_rounds(A, r, x, idxs, lam):
    """K fused Shotgun rounds: idxs is (K, p). Scanned so the weight state
    stays on-device across rounds; buffers are donated at lowering time."""

    def body(carry, idx):
        r_c, x_c = carry
        r_n, x_n = lasso_round(A, r_c, x_c, idx, lam)
        return (r_n, x_n), jnp.float32(0.0)

    (r_new, x_new), _ = jax.lax.scan(body, (r, x), idxs)
    return r_new, x_new


def lasso_objective(A, x, y, lam):
    """F(x) = 1/2 ||Ax - y||^2 + lam ||x||_1 through the matvec kernel."""
    r = K.matvec(A, x) - y
    return 0.5 * jnp.dot(r, r) + lam * jnp.sum(jnp.abs(x))


def logistic_round(A, x, y, idx, lam):
    """One Shotgun round for sparse logistic regression (fixed-beta step,
    Alg. 2; the CDN line-search variant lives in the rust coordinator).

    Returns x_new. No residual carry: the margin recomputes via the matvec
    kernel (the paper's Ax-cache trick is a sparse-path optimization that
    the rust engines implement; the dense TPU path is matmul-bound anyway).
    """
    g = K.logistic_block_grad(A, x, y, idx)
    delta = K.soft_threshold_block(x[idx], g, lam, LOGISTIC_BETA)
    return x.at[idx].add(delta)


def logistic_objective(A, x, y, lam):
    margins = y * K.matvec(A, x)
    return jnp.sum(jnp.logaddexp(0.0, -margins)) + lam * jnp.sum(jnp.abs(x))


def power_iter(A, v, steps: int):
    """`steps` power-iteration steps on A^T A; returns (v, rho_estimate).

    rho = spectral radius of A^T A, the paper's parallelism measure
    (Theorem 3.2); P* = ceil(d / rho)."""

    def body(carry, _):
        v_c, _ = carry
        v_n, nrm = K.power_iter_step(A, v_c)
        return (v_n, nrm), jnp.float32(0.0)

    (v_out, rho), _ = jax.lax.scan(body, (v, jnp.float32(0.0)), None, length=steps)
    return v_out, rho
