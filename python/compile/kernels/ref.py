"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float tolerance under pytest + hypothesis sweeps.

Conventions (shared with the kernels and the rust coordinator):
  A     : (n, d)  dense design matrix, columns normalized (diag(A^T A)=1)
  r     : (n,)    residual. Lasso: r = A x - y. Logistic: margin cache.
  x     : (d,)    weight vector (signed; the duplicate-feature trick is
                  only used in the paper's analysis, not implementations)
  idx   : (p,)    int32 coordinate block sampled for one Shotgun round
  lam   : ()      L1 regularization strength
  beta  : ()      Assumption-2.1 constant (1.0 squared loss, 0.25 logistic)
"""

from __future__ import annotations

import jax.numpy as jnp


def soft_threshold_update(x_j, g_j, lam, beta):
    """Signed soft-threshold coordinate step.

    The paper's non-negative duplicated-feature update (Alg. 1 / Eq. 5)
    folded back to signed coordinates: the closed-form minimizer of
    g_j*d + beta/2*d^2 + lam*|x_j + d| over d.
    """
    u = x_j - g_j / beta
    t = lam / beta
    x_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
    return x_new - x_j


def shotgun_block_update_ref(A, r, x, idx, lam, beta):
    """One synchronous Shotgun round on the dense Lasso.

    Returns (delta, r_new, x_new):
      g_j     = A_j^T r                  (block gradient via A_S^T r)
      delta_j = soft-threshold step per sampled coordinate
      duplicate draws in `idx` resolve by summing deltas (the multiset
      semantics of Alg. 2), matching the rust coordinator;
      r_new   = r + A_S @ delta_per_draw
      x_new   = x + scatter-add(delta)
    """
    A_S = A[:, idx]                       # (n, p)
    g = A_S.T @ r                         # (p,)
    x_S = x[idx]
    delta = soft_threshold_update(x_S, g, lam, beta)
    r_new = r + A_S @ delta
    x_new = x.at[idx].add(delta)
    return delta, r_new, x_new


def lasso_objective_ref(A, x, y, lam):
    r = A @ x - y
    return 0.5 * jnp.dot(r, r) + lam * jnp.sum(jnp.abs(x))


def logistic_probs_ref(A, x, y):
    """sigma(-y_i a_i^T x) -- per-sample weight in the logistic gradient."""
    margins = y * (A @ x)
    return 1.0 / (1.0 + jnp.exp(margins))


def logistic_objective_ref(A, x, y, lam):
    margins = y * (A @ x)
    return jnp.sum(jnp.logaddexp(0.0, -margins)) + lam * jnp.sum(jnp.abs(x))


def logistic_block_grad_ref(A, x, y, idx):
    """Block coordinate gradient of the logistic loss (no reg term):
    g_j = -sum_i y_i A_ij sigma(-y_i a_i^T x)."""
    p = logistic_probs_ref(A, x, y)
    A_S = A[:, idx]
    return -(A_S.T @ (y * p))


def power_iter_step_ref(A, v):
    """One normalized power-iteration step on A^T A. Returns (v', ||A^T A v||)."""
    w = A.T @ (A @ v)
    nrm = jnp.linalg.norm(w)
    return w / jnp.maximum(nrm, 1e-30), nrm


def matvec_ref(A, x):
    return A @ x
