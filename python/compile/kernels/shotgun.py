"""Layer-1 Pallas kernels for the Shotgun hot path (dense problems).

The paper's multicore implementation updates one coordinate per worker with
atomic CAS on a shared Ax vector and finds itself memory-wall bound (O(1)
flops per memory access, no temporal locality). The TPU adaptation (see
DESIGN.md §Hardware-Adaptation) makes one *synchronous* Shotgun round a
block computation:

    g     = A_S^T r          (n x p matmul on the MXU, A_S tiled in VMEM)
    delta = soft-threshold(x_S, g)            (VPU elementwise)
    r'    = r + A_S delta    (second MXU pass, same VMEM tiles)

which raises arithmetic intensity to O(p) flops per residual byte. The
grid iterates over n-tiles; BlockSpec expresses the HBM->VMEM schedule.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU perf is estimated in DESIGN.md from the VMEM
footprint + MXU occupancy of these BlockSpecs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default n-tile: multiple of the 8x128 VPU lane tile and big enough to
# keep the MXU busy; callers override for small/odd n.
DEFAULT_TILE_N = 256


def _grad_kernel(a_ref, r_ref, o_ref):
    """Accumulate one n-tile's contribution to g = A_S^T r.

    a_ref: (tile_n, p) VMEM tile of the gathered column block
    r_ref: (tile_n, 1) VMEM tile of the residual
    o_ref: (p, 1) accumulator; same block for every grid step.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (p, tile_n) @ (tile_n, 1) -> (p, 1) on the MXU
    o_ref[...] += jnp.dot(
        a_ref[...].T, r_ref[...], preferred_element_type=o_ref.dtype
    )


def block_grad(A_S, r, *, tile_n: int = DEFAULT_TILE_N):
    """g = A_S^T r, tiled over n. A_S: (n, p), r: (n,) -> (p,)."""
    n, p = A_S.shape
    tile_n = min(tile_n, n)
    if n % tile_n != 0:
        tile_n = n  # fall back to a single tile for ragged n
    grid = (n // tile_n,)
    out = pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, p), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((p, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), A_S.dtype),
        interpret=True,
    )(A_S, r[:, None])
    return out[:, 0]


def _delta_kernel(x_ref, g_ref, lam_ref, beta_ref, o_ref):
    """Soft-threshold step for a coordinate block (VPU elementwise).

    delta_j = S(x_j - g_j/beta, lam/beta) - x_j with S the shrinkage op.
    """
    x = x_ref[...]
    g = g_ref[...]
    beta = beta_ref[0]
    lam = lam_ref[0]
    u = x - g / beta
    t = lam / beta
    x_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)
    o_ref[...] = x_new - x


def soft_threshold_block(x_S, g, lam, beta):
    """delta for a sampled coordinate block. x_S, g: (p,) -> (p,)."""
    lam = jnp.asarray([lam], dtype=x_S.dtype)
    beta = jnp.asarray([beta], dtype=x_S.dtype)
    return pl.pallas_call(
        _delta_kernel,
        interpret=True,
        out_shape=jax.ShapeDtypeStruct(x_S.shape, x_S.dtype),
    )(x_S, g, lam, beta)


def _apply_kernel(a_ref, r_ref, d_ref, o_ref):
    """r-tile update: o = r + A_S_tile @ delta (MXU)."""
    o_ref[...] = r_ref[...] + jnp.dot(
        a_ref[...], d_ref[...], preferred_element_type=o_ref.dtype
    )


def block_apply(A_S, r, delta, *, tile_n: int = DEFAULT_TILE_N):
    """r' = r + A_S @ delta, tiled over n. -> (n,)."""
    n, p = A_S.shape
    tile_n = min(tile_n, n)
    if n % tile_n != 0:
        tile_n = n
    grid = (n // tile_n,)
    out = pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, p), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), A_S.dtype),
        interpret=True,
    )(A_S, r[:, None], delta[:, None])
    return out[:, 0]


def shotgun_block_update(A, r, x, idx, lam, beta, *, tile_n: int = DEFAULT_TILE_N):
    """One synchronous Shotgun round (dense Lasso), hot spot in Pallas.

    The column gather A[:, idx] and the x scatter-add are Layer-2 jnp (XLA
    gather/scatter are already optimal); the flops live in the kernels.
    Duplicate draws resolve by summed deltas -- Alg. 2 multiset semantics.
    Returns (delta, r_new, x_new); matches ref.shotgun_block_update_ref.
    """
    A_S = A[:, idx]
    g = block_grad(A_S, r, tile_n=tile_n)
    delta = soft_threshold_block(x[idx], g, lam, beta)
    r_new = block_apply(A_S, r, delta, tile_n=tile_n)
    x_new = x.at[idx].add(delta)
    return delta, r_new, x_new


def _matvec_kernel(a_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype)


def matvec(A, x, *, tile_n: int = DEFAULT_TILE_N):
    """A @ x tiled over rows; used for residual (re)materialization."""
    n, d = A.shape
    tile_n = min(tile_n, n)
    if n % tile_n != 0:
        tile_n = n
    grid = (n // tile_n,)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), A.dtype),
        interpret=True,
    )(A, x[:, None])
    return out[:, 0]


def _logistic_probs_kernel(m_ref, o_ref):
    """sigma(-m) elementwise on a margin tile (VPU)."""
    o_ref[...] = 1.0 / (1.0 + jnp.exp(m_ref[...]))


def logistic_probs(A, x, y, *, tile_n: int = DEFAULT_TILE_N):
    """sigma(-y * Ax): margins via the matvec kernel, link via a VPU kernel."""
    margins = y * matvec(A, x, tile_n=tile_n)
    return pl.pallas_call(
        _logistic_probs_kernel,
        interpret=True,
        out_shape=jax.ShapeDtypeStruct(margins.shape, margins.dtype),
    )(margins)


def logistic_block_grad(A, x, y, idx, *, tile_n: int = DEFAULT_TILE_N):
    """g_j = -A_S^T (y * sigma(-y Ax)) through the grad kernel."""
    w = y * logistic_probs(A, x, y, tile_n=tile_n)
    return -block_grad(A[:, idx], w, tile_n=tile_n)


def power_iter_step(A, v, *, tile_n: int = DEFAULT_TILE_N):
    """One power-iteration step on A^T A via the matvec + grad kernels.

    Returns (v', ||A^T A v||); the Rayleigh-style norm converges to rho.
    """
    Av = matvec(A, v, tile_n=tile_n)
    w = block_grad(A, Av, tile_n=tile_n)  # A^T (A v)
    nrm = jnp.linalg.norm(w)
    return w / jnp.maximum(nrm, 1e-30), nrm
