"""AOT compile path: lower the L2 entrypoints to HLO text artifacts.

Run once by `make artifacts` (no-op if inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Alongside the .hlo.txt files we write `manifest.json` describing every
artifact's entrypoint, shapes and dtypes; the rust runtime
(rust/src/runtime/artifacts.rs) is manifest-driven and never hardcodes
shapes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Shape profiles for the artifact set. `p` is the Shotgun parallelism of
# the block round; `k` the number of fused rounds in the scan variant.
# Small profiles keep CPU-PJRT execution quick in tests; `m` is the
# example/bench workhorse.
PROFILES = {
    "s": dict(n=256, d=512, p=8, k=8, power_steps=16),
    "m": dict(n=512, d=2048, p=16, k=16, power_steps=32),
}


def entries(prof: dict):
    """(name, fn, example_args) for every AOT entrypoint of one profile."""
    n, d, p, k = prof["n"], prof["d"], prof["p"], prof["k"]
    steps = prof["power_steps"]
    A = spec((n, d))
    return [
        (
            "lasso_round",
            model.lasso_round,
            (A, spec((n,)), spec((d,)), spec((p,), I32), spec(())),
        ),
        (
            "lasso_rounds",
            model.lasso_rounds,
            (A, spec((n,)), spec((d,)), spec((k, p), I32), spec(())),
        ),
        (
            "lasso_objective",
            model.lasso_objective,
            (A, spec((d,)), spec((n,)), spec(())),
        ),
        (
            "logistic_round",
            model.logistic_round,
            (A, spec((d,)), spec((n,)), spec((p,), I32), spec(())),
        ),
        (
            "logistic_objective",
            model.logistic_objective,
            (A, spec((d,)), spec((n,)), spec(())),
        ),
        (
            "power_iter",
            lambda A, v: model.power_iter(A, v, steps),
            (A, spec((d,))),
        ),
    ]


def arg_desc(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profiles", default="s,m", help="comma-separated profile tags")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"profiles": {}, "artifacts": []}
    for tag in args.profiles.split(","):
        prof = PROFILES[tag]
        manifest["profiles"][tag] = prof
        for name, fn, eargs in entries(prof):
            lowered = jax.jit(fn).lower(*eargs)
            text = to_hlo_text(lowered)
            fname = f"{name}.{tag}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "entry": name,
                    "profile": tag,
                    "file": fname,
                    "args": [arg_desc(s) for s in eargs],
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
            )
            print(f"  {fname}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
